//! Pluggable execution backends.
//!
//! A [`Backend`] evaluates the DTRNet model family over host [`Tensor`]s:
//! batched training-shape forward passes (logits + routing telemetry) and
//! incremental decode with a routing-aware KV state. Two implementations
//! exist:
//!
//! * [`crate::runtime::CpuBackend`] — native Rust, always available; the
//!   default build's execution path and the offline test substrate.
//! * The PJRT/XLA path (`pjrt` cargo feature) — AOT artifacts executed
//!   through [`crate::runtime::Engine`]; it keeps device-resident state
//!   inside [`crate::coordinator`] loops rather than implementing this
//!   trait directly (literals must stay on device across steps).
//!
//! [`DecodeState`] is the host-side analogue of the decode artifact's
//! resident KV literals: per layer, only tokens the router sent through
//! attention are cached — the mechanism behind the paper's Fig. 6 memory
//! savings. Dense layers cache every token. Storage sits behind the
//! page-view API ([`KvCache`], runtime/kv.rs): the default resident slab
//! or a bounded/paged cache with LRU spill-to-disk eviction.
//!
//! # Canonical entry points vs adapters
//!
//! [`Backend::decode_step_routed`] is the **canonical** single-step
//! primitive every implementation must provide; the batched hooks
//! ([`Backend::decode_rows`], [`Backend::decode_batch`],
//! [`Backend::prefill_rows`]) are optional overrides that must stay
//! bit-identical to a sequential `decode_step_routed` loop. Everything
//! else is an **adapter** with a final default implementation in terms
//! of those: [`Backend::decode_step`] (router-mode wrapper),
//! [`Backend::prefill_chunked`] (telemetry-discarding wrapper over
//! `prefill_rows`), [`Backend::prefill`] and [`Backend::generate`].

use std::path::PathBuf;

use anyhow::{ensure, Result};

use crate::config::ModelConfig;
use crate::coordinator::sampling::{sample, SamplingParams};
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::kv::KvCache;
use super::tensor::Tensor;

/// Batched forward outputs — mirrors the AOT `fwd` artifact tuple
/// (logits, route, g_attn, attn_frac).
#[derive(Debug, Clone)]
pub struct ForwardOutput {
    /// `[B, S, V]` next-token logits.
    pub logits: Tensor,
    /// `[B, L, S]` hard routing decisions (1.0 = attention path). Dense
    /// layers are all-ones by construction.
    pub route: Tensor,
    /// `[B, L, S]` soft attention-path router scores (1.0 on dense layers).
    pub g_attn: Tensor,
    /// `[L]` mean fraction of tokens routed to attention per layer.
    pub attn_frac: Vec<f64>,
}

/// Per-sequence incremental decode state: position counter plus per-layer
/// cached keys/values (`[len, H*hd]` row-major, RoPE already applied to
/// keys at their absolute positions — the same contract as the decode
/// artifact's cache literals).
#[derive(Debug, Clone)]
pub struct DecodeState {
    /// Tokens fed so far (the next token's absolute position).
    pub position: usize,
    /// Per-layer cached K/V (`[len, H*hd]` row-major) behind the
    /// page-view API: attention reads rows only through
    /// [`KvCache::view`], never as raw slabs.
    pub kv: KvCache,
}

impl DecodeState {
    /// An empty decode state for a model with `n_layers` layers, backed
    /// by the unbounded resident slab.
    pub fn new(n_layers: usize) -> DecodeState {
        DecodeState {
            position: 0,
            kv: KvCache::resident(n_layers),
        }
    }

    /// An empty decode state backed by the bounded/paged cache: at most
    /// `budget_pages` pages (of `page_rows` rows) resident at once, LRU
    /// overflow spilled to a file under `spill_dir` (OS temp dir when
    /// `None`). Bitwise-identical decode to [`DecodeState::new`] — the
    /// budget only bounds *memory*, never what attention sees.
    pub fn bounded(
        n_layers: usize,
        d_model: usize,
        page_rows: usize,
        budget_pages: usize,
        spill_dir: Option<PathBuf>,
    ) -> DecodeState {
        DecodeState {
            position: 0,
            kv: KvCache::bounded(n_layers, d_model, page_rows, budget_pages, spill_dir),
        }
    }

    /// Cached token count per layer (the artifact's `lens` row).
    pub fn lens(&self, d_model: usize) -> Vec<usize> {
        self.kv.lens(d_model)
    }

    /// Flat per-layer `(keys, values)` copies — the equality surface for
    /// tests and tools (spilled pages are read back; bit-exact).
    pub fn snapshot_kv(&self) -> Vec<(Vec<f32>, Vec<f32>)> {
        self.kv.snapshot()
    }

    /// Snapshot the current extent (position + per-layer cached token
    /// counts) for a later [`DecodeState::rollback`]. Cheap by design:
    /// the caches are append-only, so an extent snapshot is enough to
    /// restore the exact pre-draft bytes — no copy of the rows
    /// themselves is needed.
    pub fn mark(&self, d_model: usize) -> StateMark {
        StateMark {
            position: self.position,
            lens: self.lens(d_model),
        }
    }

    /// Roll the state back to `lens` cached tokens per layer and
    /// `position` tokens fed. Because the caches are append-only,
    /// truncation is a bitwise restore of any earlier extent — the
    /// speculative-decode rejection path.
    pub fn truncate_to(&mut self, lens: &[usize], position: usize, d_model: usize) {
        self.kv.truncate(lens, d_model);
        self.position = position;
    }

    /// Roll back to a [`StateMark`] taken earlier on this state.
    pub fn rollback(&mut self, mark: &StateMark, d_model: usize) {
        self.truncate_to(&mark.lens, mark.position, d_model);
    }
}

/// Extent snapshot of a [`DecodeState`], taken via [`DecodeState::mark`]
/// before a speculative draft window so the state can be rolled back
/// bitwise on rejection ([`DecodeState::rollback`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StateMark {
    /// `position` at snapshot time.
    pub position: usize,
    /// Per-layer cached token count at snapshot time.
    pub lens: Vec<usize>,
}

/// Per-call routing override for [`Backend::decode_step_routed`].
///
/// `Router` follows the model's routing decisions unchanged (exactly
/// [`Backend::decode_step`]). `ForceBypass` pins every DTR layer onto
/// the linear bypass path — the router weights are untouched and its
/// soft score still scales the bypass update, but no DTR layer attends
/// or caches KV. Dense layers always attend (and cache) either way.
/// ForceBypass turns a decode step into the cheap draft pass of
/// bypass-path speculative decoding (DESIGN.md §Speculative decoding).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteOverride {
    /// Follow the router (normal decode).
    Router,
    /// Pin every DTR layer onto the linear bypass (draft mode).
    ForceBypass,
}

/// One decode step's outputs — mirrors the decode artifact tuple
/// (logits, routing decision per layer, soft scores per layer).
#[derive(Debug, Clone)]
pub struct StepOutput {
    /// `[V]` logits for the next token.
    pub logits: Tensor,
    /// Per-layer: did this token take the attention path (and get cached)?
    pub routed: Vec<bool>,
    /// Per-layer soft attention score g_attn (1.0 on dense layers).
    pub g_attn: Vec<f32>,
}

/// Outcome of [`Backend::prefill_rows`]: the last step's output plus the
/// per-row routing telemetry that plain prefill discards.
#[derive(Debug, Clone)]
pub struct PrefillRows {
    /// The final step's output (logits predict the token after the prompt).
    pub last: StepOutput,
    /// `routed[row][layer]`: did prompt token `row` take the attention path?
    pub routed: Vec<Vec<bool>>,
    /// `g_attn[row][layer]`: soft attention-path score per prompt token.
    pub g_attn: Vec<Vec<f32>>,
}

/// Outcome of [`Backend::generate`].
#[derive(Debug, Clone)]
pub struct GenerateOutput {
    /// Generated token ids (prompt not included).
    pub tokens: Vec<i32>,
    /// Per-layer fraction of tokens fed through the model that took the
    /// attention path. Covers the prompt plus all but the last generated
    /// token (the final sample is returned without a decode step).
    pub attn_frac: Vec<f64>,
}

/// Default chunk width for [`Backend::prefill`] (balances batched-kernel
/// amortization against scratch memory; any value is correct).
pub const PREFILL_CHUNK: usize = 32;

/// Weight-memory telemetry: what a backend's parameters actually occupy
/// versus their f32-equivalent footprint. For full-precision backends
/// the two are equal; the int8 backend
/// ([`crate::runtime::quant::QuantizedCpuBackend`]) reports ~3.7×
/// compression. Folded into [`crate::coordinator::ServeReport`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WeightBytes {
    /// Bytes the weights occupy as resident in this backend.
    pub resident: usize,
    /// Bytes the same parameter set occupies at f32 (4 bytes/param).
    pub f32_equiv: usize,
}

impl WeightBytes {
    /// Compression ratio vs f32 (`f32_equiv / resident`; 1.0 for
    /// full-precision backends).
    pub fn compression(&self) -> f64 {
        self.f32_equiv as f64 / self.resident.max(1) as f64
    }
}

/// An execution backend for the DTRNet model family.
pub trait Backend {
    /// Human-readable backend name (for logs/reports).
    fn name(&self) -> &'static str;

    /// The model configuration this backend instance was built for.
    fn config(&self) -> &ModelConfig;

    /// Per-kernel wall-clock accounting snapshot (the
    /// [`crate::metrics::KernelTimers`] JSON schema: one
    /// `{calls, total_ms, mean_us}` object per hot section plus a summed
    /// `total_ms`), if this backend records one. The serving engine folds
    /// it into [`crate::coordinator::ServeReport`] and the `bench`
    /// harness writes it into `BENCH_*.json`. Default: `None`.
    fn kernel_timings(&self) -> Option<Json> {
        None
    }

    /// Measured FLOP counters ([`crate::telemetry::FlopCounters`]), if
    /// this backend instruments its kernels. Counters accumulate across
    /// calls; callers reset them between measurement windows. The serving
    /// engine folds per-layer measured-vs-dense ratios into
    /// [`crate::coordinator::ServeReport`]; tests reconcile them against
    /// the [`crate::model::flops`] analytic model. Default: `None`.
    fn flop_counters(&self) -> Option<&crate::telemetry::FlopCounters> {
        None
    }

    /// Weight-memory telemetry (resident vs f32-equivalent bytes). The
    /// default assumes full-precision residency: `param_count × 4` on
    /// both sides. Quantized backends override with measured bytes.
    fn weight_bytes(&self) -> WeightBytes {
        let bytes = self.config().param_count() * 4;
        WeightBytes {
            resident: bytes,
            f32_equiv: bytes,
        }
    }

    /// Batched training-shape forward. `tokens` is `[B, S]` i32.
    fn forward(&self, tokens: &Tensor) -> Result<ForwardOutput>;

    /// Fresh decode state for one sequence.
    fn begin_decode(&self) -> DecodeState;

    /// **Canonical decode primitive.** Feed one token at the state's
    /// current position with a per-call routing override; returns
    /// next-token logits and the per-layer routing decisions that
    /// updated the cache.
    ///
    /// [`RouteOverride::Router`] follows the model's router (normal
    /// decode — exactly [`Backend::decode_step`]);
    /// [`RouteOverride::ForceBypass`] runs the draft pass of
    /// speculative decoding (every DTR layer takes the linear bypass;
    /// router weights untouched). Draft-mode KV writes (dense layers
    /// still cache) land in `state` like any other step — callers roll
    /// them back with [`DecodeState::rollback`]. Every other decode
    /// entry point reduces to this one; the batched hooks must stay
    /// bit-identical to a sequential loop over it.
    fn decode_step_routed(
        &self,
        state: &mut DecodeState,
        token: i32,
        route: RouteOverride,
    ) -> Result<StepOutput>;

    /// Adapter: [`Backend::decode_step_routed`] pinned to
    /// [`RouteOverride::Router`] (normal decode).
    fn decode_step(&self, state: &mut DecodeState, token: i32) -> Result<StepOutput> {
        self.decode_step_routed(state, token, RouteOverride::Router)
    }

    /// Feed `tokens` to one sequence and return **every** row's step
    /// output (per-row logits, routing decision, soft score) — the
    /// batched verification pass of speculative decoding.
    ///
    /// Same bit-identity contract as [`Backend::decode_batch`]: the
    /// outputs and cache updates must equal a sequential
    /// [`Backend::decode_step`] loop over `tokens`. The default
    /// implementation is that loop; the CPU backends override it with
    /// one batched all-rows step so a k-token draft is verified in a
    /// single full-router pass.
    fn decode_rows(&self, state: &mut DecodeState, tokens: &[i32]) -> Result<Vec<StepOutput>> {
        ensure!(!tokens.is_empty(), "decode_rows needs at least one token");
        tokens
            .iter()
            .map(|&t| self.decode_step_routed(state, t, RouteOverride::Router))
            .collect()
    }

    /// Batched multi-sequence decode: feed one token to each sequence in
    /// `states` (a slab of independent per-sequence decode states) and
    /// return one [`StepOutput`] per sequence, in order.
    ///
    /// Contract: the outputs and cache updates must be **bit-identical**
    /// to calling [`Backend::decode_step`] on each (state, token) pair
    /// sequentially — batching is an execution-strategy choice, never a
    /// semantics choice (the serving engine's determinism guarantee rests
    /// on this). The default implementation is that loop; backends
    /// override it to share work across the batch.
    fn decode_batch(
        &self,
        states: &mut [&mut DecodeState],
        tokens: &[i32],
    ) -> Result<Vec<StepOutput>> {
        ensure!(
            states.len() == tokens.len(),
            "decode_batch: {} states vs {} tokens",
            states.len(),
            tokens.len()
        );
        states
            .iter_mut()
            .zip(tokens)
            .map(|(s, &t)| self.decode_step_routed(s, t, RouteOverride::Router))
            .collect()
    }

    /// Prefill like [`Backend::prefill_rows`] but report only the last
    /// step's output (logits predict the token after the prompt) —
    /// the adapter callers use when per-row telemetry isn't needed.
    ///
    /// Same bit-identity contract as [`Backend::decode_batch`]: the cache
    /// contents, per-layer lens, and final logits must equal a sequential
    /// [`Backend::decode_step`] loop for any chunk size.
    fn prefill_chunked(
        &self,
        state: &mut DecodeState,
        tokens: &[i32],
        chunk: usize,
    ) -> Result<StepOutput> {
        Ok(self.prefill_rows(state, tokens, chunk)?.last)
    }

    /// Prefill `tokens` in chunks of up to `chunk` tokens, returning
    /// every prompt row's routing decision and soft score plus the last
    /// step's output. Same bit-identity contract: state/logits must
    /// equal the sequential decode loop. The default implementation *is*
    /// that loop; backends with batched prefill kernels override it to
    /// process whole chunks at once (streaming chunked prefill — the
    /// long-context path runs 32k+ prompts through this hook).
    fn prefill_rows(
        &self,
        state: &mut DecodeState,
        tokens: &[i32],
        chunk: usize,
    ) -> Result<PrefillRows> {
        ensure!(!tokens.is_empty(), "prefill needs at least one token");
        let _ = chunk;
        let mut routed = Vec::with_capacity(tokens.len());
        let mut g_attn = Vec::with_capacity(tokens.len());
        let mut last = None;
        for &t in tokens {
            let step = self.decode_step_routed(state, t, RouteOverride::Router)?;
            routed.push(step.routed.clone());
            g_attn.push(step.g_attn.clone());
            last = Some(step);
        }
        Ok(PrefillRows {
            last: last.unwrap(),
            routed,
            g_attn,
        })
    }

    /// Prefill a prompt; returns the last step's output (logits predict
    /// the token after the prompt). Delegates to
    /// [`Backend::prefill_chunked`] with [`PREFILL_CHUNK`], so backends
    /// that implement the chunked hook get non-sequential prefill here
    /// and in [`Backend::generate`] for free.
    fn prefill(&self, state: &mut DecodeState, tokens: &[i32]) -> Result<StepOutput> {
        self.prefill_chunked(state, tokens, PREFILL_CHUNK)
    }

    /// Greedy/sampled autoregressive decode: prefill `prompt`, then sample
    /// `max_new_tokens` continuation tokens under `params` (temperature 0
    /// = greedy). Deterministic given (`prompt`, `params`, `rng` seed).
    fn generate(
        &self,
        prompt: &[i32],
        max_new_tokens: usize,
        params: &SamplingParams,
        rng: &mut Rng,
    ) -> Result<GenerateOutput> {
        let mut state = self.begin_decode();
        let mut step = self.prefill(&mut state, prompt)?;
        // prefill() reports only its last step; the prompt's per-layer
        // routed counts are exactly the cache lens after prefill.
        let mut routed_counts: Vec<u64> = state
            .lens(self.config().d_model)
            .iter()
            .map(|&len| len as u64)
            .collect();
        let mut total_steps = prompt.len() as u64;

        let mut out_tokens: Vec<i32> = Vec::with_capacity(max_new_tokens);
        for _ in 0..max_new_tokens {
            let next = sample(step.logits.as_f32(), params, &out_tokens, rng);
            out_tokens.push(next);
            if out_tokens.len() == max_new_tokens {
                break;
            }
            step = self.decode_step(&mut state, next)?;
            total_steps += 1;
            for (l, &r) in step.routed.iter().enumerate() {
                routed_counts[l] += u64::from(r);
            }
        }

        let attn_frac = routed_counts
            .iter()
            .map(|&c| c as f64 / (total_steps as f64).max(1.0))
            .collect();
        Ok(GenerateOutput {
            tokens: out_tokens,
            attn_frac,
        })
    }
}
