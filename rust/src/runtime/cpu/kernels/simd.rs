//! Vectorized kernel inner loops with runtime tier dispatch.
//!
//! Policy (tier selection, the `--simd` / `--precision` knobs) lives in
//! [`crate::util::simd`]; this module holds the implementations plus
//! their scalar twins, organized around the determinism contract of
//! DESIGN.md §SIMD dispatch:
//!
//! * **Element-wise ops are bit-exact on every tier.** [`axpy`]
//!   (`out[i] += s * b[i]`, the matmul/attention weighted-sum inner
//!   loop) has no cross-lane interaction: the vector form performs the
//!   same one-rounding multiply and one-rounding add per element as the
//!   scalar loop, in any lane order, so the bits cannot differ. No
//!   fused multiply-add is used — FMA's single rounding would diverge
//!   from the scalar twin.
//! * **The int8 dot is bit-exact by a fixed striped order.** [`dot_q8`]
//!   defines its accumulation as [`LANES`] independent partial sums
//!   (lane `l` sums elements `l, l+8, l+16, …` of the full 8-chunks), a
//!   sequential tail for `len % 8` trailing elements, and one fixed
//!   reduction tree `((l0+l4)+(l2+l6)) + ((l1+l5)+(l3+l7)) + tail` —
//!   exactly the horizontal-add sequence the AVX2/NEON code performs.
//!   The scalar fallback implements the *same* order, so every tier
//!   agrees bitwise; `rust/tests/simd_differential.rs` pins this across
//!   a remainder-hostile shape matrix.
//! * **f32 reductions are tolerance-gated, not bit-exact.** [`dot_f32`]
//!   and [`sum_sq`] keep the one-accumulator ascending scalar order
//!   under [`Precision::Exact`]; under [`Precision::Fast`] they switch
//!   to the striped order above, which changes rounding vs the exact
//!   path (still deterministic per (tier, precision)). The bench
//!   harness gates the drift via routing-equivalence + perplexity
//!   deltas (`perf` `simd_fast_*` scenarios).
//!
//! All `unsafe` here is `target_feature` dispatch: the AVX2 entry
//! points are only reachable after `is_x86_feature_detected!` proved
//! the ISA (tier construction in `util::simd` enforces it), and every
//! pointer access stays within caller-checked slice bounds.

pub use crate::util::simd::{detect, KernelCtx, Precision, SimdTier};

/// Stripe width of the fixed accumulation order (f32 lanes in a 256-bit
/// vector; NEON uses two 128-bit halves to make up the same 8 lanes).
pub const LANES: usize = 8;

/// `out[i] += s * b[i]` — the matmul k-step / attention weighted-sum
/// inner loop. Bit-identical across all tiers (element-wise; see module
/// docs), so it is always dispatched, independent of precision.
#[inline]
pub fn axpy(tier: SimdTier, out: &mut [f32], s: f32, b: &[f32]) {
    debug_assert_eq!(out.len(), b.len());
    match tier {
        #[cfg(target_arch = "x86_64")]
        SimdTier::Avx2 => unsafe { axpy_avx2(out, s, b) },
        #[cfg(target_arch = "aarch64")]
        SimdTier::Neon => unsafe { axpy_neon(out, s, b) },
        _ => axpy_scalar(out, s, b),
    }
}

/// Scalar twin of [`axpy`] (also the fallback tier's implementation).
#[inline]
pub fn axpy_scalar(out: &mut [f32], s: f32, b: &[f32]) {
    for (o, &bv) in out.iter_mut().zip(b) {
        *o += s * bv;
    }
}

/// f32 × i8 dot product in the fixed striped accumulation order (module
/// docs). Bit-identical across all tiers by construction — the scalar
/// twin and the vector paths perform the same roundings in the same
/// order — which is what keeps the int8 backend's outputs independent
/// of the `--simd` flag.
#[inline]
pub fn dot_q8(tier: SimdTier, a: &[f32], q: &[i8]) -> f32 {
    debug_assert_eq!(a.len(), q.len());
    match tier {
        #[cfg(target_arch = "x86_64")]
        SimdTier::Avx2 => unsafe { dot_q8_avx2(a, q) },
        #[cfg(target_arch = "aarch64")]
        SimdTier::Neon => unsafe { dot_q8_neon(a, q) },
        _ => dot_q8_scalar(a, q),
    }
}

/// Scalar twin of [`dot_q8`]: the striped order spelled out in plain
/// loops. This *is* the reference semantics — the differential tests
/// hold the vector paths to it bitwise.
pub fn dot_q8_scalar(a: &[f32], q: &[i8]) -> f32 {
    let mut lanes = [0.0f32; LANES];
    let chunks = a.len() / LANES;
    for c in 0..chunks {
        for l in 0..LANES {
            let i = c * LANES + l;
            lanes[l] += a[i] * q[i] as f32;
        }
    }
    let mut tail = 0.0f32;
    for i in chunks * LANES..a.len() {
        tail += a[i] * q[i] as f32;
    }
    reduce_lanes(&lanes) + tail
}

/// f32 dot product. [`Precision::Exact`]: one-accumulator ascending
/// order on every tier (bit-identical to the historical scalar kernel).
/// [`Precision::Fast`]: striped order, vectorized where the tier
/// allows.
#[inline]
pub fn dot_f32(ctx: KernelCtx, a: &[f32], b: &[f32]) -> f32 {
    if ctx.precision == Precision::Exact {
        return dot_seq(a, b);
    }
    match ctx.tier {
        #[cfg(target_arch = "x86_64")]
        SimdTier::Avx2 => unsafe { dot_f32_avx2(a, b) },
        #[cfg(target_arch = "aarch64")]
        SimdTier::Neon => unsafe { dot_f32_neon(a, b) },
        _ => dot_f32_striped(a, b),
    }
}

/// The exact-precision reference: single accumulator, ascending index.
#[inline]
pub fn dot_seq(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(&x, &y)| x * y).sum()
}

/// Scalar twin of the fast-precision [`dot_f32`] (striped order).
pub fn dot_f32_striped(a: &[f32], b: &[f32]) -> f32 {
    let mut lanes = [0.0f32; LANES];
    let chunks = a.len() / LANES;
    for c in 0..chunks {
        for l in 0..LANES {
            let i = c * LANES + l;
            lanes[l] += a[i] * b[i];
        }
    }
    let mut tail = 0.0f32;
    for i in chunks * LANES..a.len() {
        tail += a[i] * b[i];
    }
    reduce_lanes(&lanes) + tail
}

/// Sum of squares (the rmsnorm variance reduction). Same precision
/// split as [`dot_f32`].
#[inline]
pub fn sum_sq(ctx: KernelCtx, x: &[f32]) -> f32 {
    if ctx.precision == Precision::Exact {
        return x.iter().map(|&v| v * v).sum();
    }
    match ctx.tier {
        #[cfg(target_arch = "x86_64")]
        SimdTier::Avx2 => unsafe { sum_sq_avx2(x) },
        #[cfg(target_arch = "aarch64")]
        SimdTier::Neon => unsafe { sum_sq_neon(x) },
        _ => sum_sq_striped(x),
    }
}

/// Scalar twin of the fast-precision [`sum_sq`] (striped order).
pub fn sum_sq_striped(x: &[f32]) -> f32 {
    let mut lanes = [0.0f32; LANES];
    let chunks = x.len() / LANES;
    for c in 0..chunks {
        for l in 0..LANES {
            let v = x[c * LANES + l];
            lanes[l] += v * v;
        }
    }
    let mut tail = 0.0f32;
    for &v in &x[chunks * LANES..] {
        tail += v * v;
    }
    reduce_lanes(&lanes) + tail
}

/// The fixed horizontal reduction tree shared by every striped path:
/// `((l0+l4)+(l2+l6)) + ((l1+l5)+(l3+l7))` — the exact add sequence of
/// the AVX2 `extractf128/movehl/shuffle` horizontal sum, so the scalar
/// twin reproduces the vector bits.
#[inline]
fn reduce_lanes(l: &[f32; LANES]) -> f32 {
    let s0 = l[0] + l[4];
    let s1 = l[1] + l[5];
    let s2 = l[2] + l[6];
    let s3 = l[3] + l[7];
    (s0 + s2) + (s1 + s3)
}

// ---------------------------------------------------------------------
// AVX2 (x86-64)
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::LANES;
    use std::arch::x86_64::*;

    /// Horizontal sum matching [`super::reduce_lanes`] bit-for-bit:
    /// low/high 128-bit add gives `[l0+l4, l1+l5, l2+l6, l3+l7]`, the
    /// movehl add gives `[s0+s2, s1+s3]`, the final shuffle add their
    /// sum.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum(acc: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(acc);
        let hi = _mm256_extractf128_ps(acc, 1);
        let s = _mm_add_ps(lo, hi);
        let t = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let u = _mm_add_ss(t, _mm_shuffle_ps(t, t, 0b01));
        _mm_cvtss_f32(u)
    }

    /// # Safety
    /// Requires AVX2 (caller dispatches via a detected tier).
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy(out: &mut [f32], s: f32, b: &[f32]) {
        let n = out.len().min(b.len());
        let chunks = n / LANES;
        let sv = _mm256_set1_ps(s);
        let op = out.as_mut_ptr();
        let bp = b.as_ptr();
        for c in 0..chunks {
            let i = c * LANES;
            let bv = _mm256_loadu_ps(bp.add(i));
            let ov = _mm256_loadu_ps(op.add(i));
            // mul then add (not FMA): same two roundings as the scalar
            // `*o += s * bv`, so bits match the scalar twin exactly.
            let r = _mm256_add_ps(ov, _mm256_mul_ps(sv, bv));
            _mm256_storeu_ps(op.add(i), r);
        }
        for i in chunks * LANES..n {
            *out.get_unchecked_mut(i) += s * *b.get_unchecked(i);
        }
    }

    /// # Safety
    /// Requires AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_q8(a: &[f32], q: &[i8]) -> f32 {
        let n = a.len().min(q.len());
        let chunks = n / LANES;
        let mut acc = _mm256_setzero_ps();
        let ap = a.as_ptr();
        let qp = q.as_ptr();
        for c in 0..chunks {
            let i = c * LANES;
            let av = _mm256_loadu_ps(ap.add(i));
            // 8 × i8 → sign-extend → i32 → f32: exact conversions.
            let qbytes = _mm_loadl_epi64(qp.add(i) as *const __m128i);
            let qv = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(qbytes));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(av, qv));
        }
        let mut tail = 0.0f32;
        for i in chunks * LANES..n {
            tail += *a.get_unchecked(i) * *q.get_unchecked(i) as f32;
        }
        hsum(acc) + tail
    }

    /// # Safety
    /// Requires AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let chunks = n / LANES;
        let mut acc = _mm256_setzero_ps();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        for c in 0..chunks {
            let i = c * LANES;
            let av = _mm256_loadu_ps(ap.add(i));
            let bv = _mm256_loadu_ps(bp.add(i));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(av, bv));
        }
        let mut tail = 0.0f32;
        for i in chunks * LANES..n {
            tail += *a.get_unchecked(i) * *b.get_unchecked(i);
        }
        hsum(acc) + tail
    }

    /// # Safety
    /// Requires AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn sum_sq(x: &[f32]) -> f32 {
        let n = x.len();
        let chunks = n / LANES;
        let mut acc = _mm256_setzero_ps();
        let xp = x.as_ptr();
        for c in 0..chunks {
            let v = _mm256_loadu_ps(xp.add(c * LANES));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(v, v));
        }
        let mut tail = 0.0f32;
        for i in chunks * LANES..n {
            let v = *x.get_unchecked(i);
            tail += v * v;
        }
        hsum(acc) + tail
    }
}

#[cfg(target_arch = "x86_64")]
use avx2::{axpy as axpy_avx2, dot_f32 as dot_f32_avx2, dot_q8 as dot_q8_avx2, sum_sq as sum_sq_avx2};

// ---------------------------------------------------------------------
// NEON (aarch64)
// ---------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::LANES;
    use std::arch::aarch64::*;

    /// Horizontal sum matching [`super::reduce_lanes`]: the two
    /// 128-bit halves hold lanes 0–3 and 4–7, so one vector add gives
    /// `[s0, s1, s2, s3]` and the scalar tree finishes identically.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn hsum(lo: float32x4_t, hi: float32x4_t) -> f32 {
        let s = vaddq_f32(lo, hi);
        let s0 = vgetq_lane_f32(s, 0);
        let s1 = vgetq_lane_f32(s, 1);
        let s2 = vgetq_lane_f32(s, 2);
        let s3 = vgetq_lane_f32(s, 3);
        (s0 + s2) + (s1 + s3)
    }

    /// # Safety
    /// Requires NEON (caller dispatches via a detected tier).
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy(out: &mut [f32], s: f32, b: &[f32]) {
        let n = out.len().min(b.len());
        let chunks = n / LANES;
        let sv = vdupq_n_f32(s);
        let op = out.as_mut_ptr();
        let bp = b.as_ptr();
        for c in 0..chunks {
            let i = c * LANES;
            // mul then add (no fused op) to match scalar rounding.
            let r0 = vaddq_f32(vld1q_f32(op.add(i)), vmulq_f32(sv, vld1q_f32(bp.add(i))));
            let r1 = vaddq_f32(
                vld1q_f32(op.add(i + 4)),
                vmulq_f32(sv, vld1q_f32(bp.add(i + 4))),
            );
            vst1q_f32(op.add(i), r0);
            vst1q_f32(op.add(i + 4), r1);
        }
        for i in chunks * LANES..n {
            *out.get_unchecked_mut(i) += s * *b.get_unchecked(i);
        }
    }

    /// # Safety
    /// Requires NEON.
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_q8(a: &[f32], q: &[i8]) -> f32 {
        let n = a.len().min(q.len());
        let chunks = n / LANES;
        let mut acc_lo = vdupq_n_f32(0.0);
        let mut acc_hi = vdupq_n_f32(0.0);
        let ap = a.as_ptr();
        let qp = q.as_ptr();
        for c in 0..chunks {
            let i = c * LANES;
            let qw = vmovl_s8(vld1_s8(qp.add(i))); // 8 × i16
            let q_lo = vcvtq_f32_s32(vmovl_s16(vget_low_s16(qw)));
            let q_hi = vcvtq_f32_s32(vmovl_s16(vget_high_s16(qw)));
            acc_lo = vaddq_f32(acc_lo, vmulq_f32(vld1q_f32(ap.add(i)), q_lo));
            acc_hi = vaddq_f32(acc_hi, vmulq_f32(vld1q_f32(ap.add(i + 4)), q_hi));
        }
        let mut tail = 0.0f32;
        for i in chunks * LANES..n {
            tail += *a.get_unchecked(i) * *q.get_unchecked(i) as f32;
        }
        hsum(acc_lo, acc_hi) + tail
    }

    /// # Safety
    /// Requires NEON.
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let chunks = n / LANES;
        let mut acc_lo = vdupq_n_f32(0.0);
        let mut acc_hi = vdupq_n_f32(0.0);
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        for c in 0..chunks {
            let i = c * LANES;
            acc_lo = vaddq_f32(acc_lo, vmulq_f32(vld1q_f32(ap.add(i)), vld1q_f32(bp.add(i))));
            acc_hi = vaddq_f32(
                acc_hi,
                vmulq_f32(vld1q_f32(ap.add(i + 4)), vld1q_f32(bp.add(i + 4))),
            );
        }
        let mut tail = 0.0f32;
        for i in chunks * LANES..n {
            tail += *a.get_unchecked(i) * *b.get_unchecked(i);
        }
        hsum(acc_lo, acc_hi) + tail
    }

    /// # Safety
    /// Requires NEON.
    #[target_feature(enable = "neon")]
    pub unsafe fn sum_sq(x: &[f32]) -> f32 {
        let n = x.len();
        let chunks = n / LANES;
        let mut acc_lo = vdupq_n_f32(0.0);
        let mut acc_hi = vdupq_n_f32(0.0);
        let xp = x.as_ptr();
        for c in 0..chunks {
            let v0 = vld1q_f32(xp.add(c * LANES));
            let v1 = vld1q_f32(xp.add(c * LANES + 4));
            acc_lo = vaddq_f32(acc_lo, vmulq_f32(v0, v0));
            acc_hi = vaddq_f32(acc_hi, vmulq_f32(v1, v1));
        }
        let mut tail = 0.0f32;
        for i in chunks * LANES..n {
            let v = *x.get_unchecked(i);
            tail += v * v;
        }
        hsum(acc_lo, acc_hi) + tail
    }
}

#[cfg(target_arch = "aarch64")]
use neon::{axpy as axpy_neon, dot_f32 as dot_f32_neon, dot_q8 as dot_q8_neon, sum_sq as sum_sq_neon};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randn(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32 * scale).collect()
    }

    /// Shape matrix hostile to vector code: remainders around the lane
    /// width, the empty slice, and single elements.
    const SIZES: [usize; 12] = [0, 1, 3, 7, 8, 9, 15, 16, 17, 31, 64, 257];

    #[test]
    fn axpy_bits_match_scalar_on_every_supported_tier() {
        let mut rng = Rng::new(71);
        for &n in &SIZES {
            let b = randn(&mut rng, n, 1.3);
            let base = randn(&mut rng, n, 0.7);
            let s = rng.normal() as f32;
            let mut want = base.clone();
            axpy_scalar(&mut want, s, &b);
            for t in [SimdTier::Avx2, SimdTier::Neon, SimdTier::Scalar] {
                if !t.supported() {
                    continue;
                }
                let mut got = base.clone();
                axpy(t, &mut got, s, &b);
                assert_eq!(want, got, "axpy bits diverged on {} at n={n}", t.name());
            }
        }
    }

    #[test]
    fn dot_q8_bits_match_striped_scalar_on_every_supported_tier() {
        let mut rng = Rng::new(72);
        for &n in &SIZES {
            let a = randn(&mut rng, n, 1.0);
            let q: Vec<i8> = (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let want = dot_q8_scalar(&a, &q);
            for t in [SimdTier::Avx2, SimdTier::Neon, SimdTier::Scalar] {
                if !t.supported() {
                    continue;
                }
                let got = dot_q8(t, &a, &q);
                assert_eq!(
                    want.to_bits(),
                    got.to_bits(),
                    "dot_q8 bits diverged on {} at n={n}",
                    t.name()
                );
            }
        }
    }

    #[test]
    fn fast_reductions_match_their_striped_scalar_twin_bitwise() {
        let mut rng = Rng::new(73);
        let fast = KernelCtx::scalar().with_precision(Precision::Fast);
        for &n in &SIZES {
            let a = randn(&mut rng, n, 1.0);
            let b = randn(&mut rng, n, 1.0);
            for t in [SimdTier::Avx2, SimdTier::Neon, SimdTier::Scalar] {
                if !t.supported() {
                    continue;
                }
                let ctx = fast.with_tier(t);
                assert_eq!(
                    dot_f32_striped(&a, &b).to_bits(),
                    dot_f32(ctx, &a, &b).to_bits(),
                    "fast dot bits diverged on {} at n={n}",
                    t.name()
                );
                assert_eq!(
                    sum_sq_striped(&a).to_bits(),
                    sum_sq(ctx, &a).to_bits(),
                    "fast sum_sq bits diverged on {} at n={n}",
                    t.name()
                );
            }
        }
    }

    #[test]
    fn exact_precision_ignores_the_tier() {
        // Under Exact, dot/sum_sq use the sequential order on every
        // tier — the whole f32 pipeline stays bit-identical across
        // `--simd` settings.
        let mut rng = Rng::new(74);
        let a = randn(&mut rng, 100, 1.0);
        let b = randn(&mut rng, 100, 1.0);
        for t in [SimdTier::Avx2, SimdTier::Neon, SimdTier::Scalar] {
            let ctx = KernelCtx::scalar().with_tier(t); // precision Exact
            assert_eq!(dot_f32(ctx, &a, &b).to_bits(), dot_seq(&a, &b).to_bits());
            let ssq: f32 = a.iter().map(|&v| v * v).sum();
            assert_eq!(sum_sq(ctx, &a).to_bits(), ssq.to_bits());
        }
    }

    #[test]
    fn striped_order_is_close_to_sequential() {
        // Sanity: striping only reorders the sum — values stay within
        // a tight relative tolerance of the sequential reference.
        let mut rng = Rng::new(75);
        let a = randn(&mut rng, 1000, 1.0);
        let b = randn(&mut rng, 1000, 1.0);
        let seq = dot_seq(&a, &b) as f64;
        let striped = dot_f32_striped(&a, &b) as f64;
        assert!((seq - striped).abs() <= 1e-4 * seq.abs().max(1.0));
    }
}
