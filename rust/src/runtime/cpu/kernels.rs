//! Native CPU kernels — the Rust mirror of `python/compile/kernels/ref.py`.
//!
//! Every function here has a line-for-line oracle in ref.py and is held to
//! it by the golden-vector suite (`rust/tests/golden_ref.rs`, fixtures
//! exported by `python/compile/kernels/export_fixtures.py`) to 1e-4.
//!
//! Conventions (paper notation): `n` sequence length, `d` model dim, `h`
//! heads, `hd` head dim (`d = h * hd`). All buffers are flat row-major
//! `f32` slices; `[n, h, hd]` tensors index as `(i*h + head)*hd + t`.
//!
//! # Parallel execution
//!
//! Hot kernels come in two forms: the plain name (serial, the ref.py
//! oracle mirror) and a `_par` variant taking a
//! [`Pool`](crate::util::threadpool::Pool). Both run the *same* loop
//! body over disjoint row/column chunks, and every per-element floating
//! accumulation happens in a fixed order (ascending `k` for matmuls,
//! cache-then-pending-then-self for attention), so the parallel form is
//! **bit-identical** to the serial form for every thread count —
//! `rust/tests/properties_backend.rs` pins this bitwise. Tiny regions
//! run inline (no dispatch); see the threshold constants below.
//!
//! # SIMD dispatch
//!
//! The inner loops route through [`simd`] (AVX2 / NEON with the scalar
//! loop as the always-available fallback), dispatched by the
//! [`KernelCtx`](crate::util::simd::KernelCtx) carried on the [`Pool`]
//! (the `--simd` / `--precision` CLI knobs). Under the default exact
//! precision the tier is a pure throughput knob — every kernel is
//! bit-identical across tiers; `--precision fast` additionally
//! vectorizes the f32 dot/variance reductions at tolerance-gated
//! rounding drift. See DESIGN.md §SIMD dispatch and the contract notes
//! in [`simd`].

pub mod simd;

use crate::runtime::kv::KvPageRef;
use crate::util::simd::{KernelCtx, SimdTier};
use crate::util::threadpool::Pool;

/// Large-negative instead of -inf: keeps softmax NaN-free (ref.py NEG_INF).
pub const NEG_INF: f32 = -1.0e30;

/// Below this many multiply-adds a kernel skips the pool entirely.
const PAR_MIN_FLOPS: usize = 16 * 1024;

/// Target multiply-adds per parallel chunk (the pool's work grain) —
/// shared with the backend's attention row chunking (`attend_rows`).
pub(crate) const PAR_CHUNK_FLOPS: usize = 8 * 1024;

/// k-dimension tile for [`matmul`]: keeps a `K_BLOCK × m` panel of `b`
/// hot in cache across the rows of a chunk. Blocks are walked in
/// ascending order, so per-element accumulation order — and therefore
/// the result bits — match the untiled loop.
const K_BLOCK: usize = 64;

/// SiLU activation: `x * sigmoid(x)`.
#[inline]
pub fn silu(x: f32) -> f32 {
    x * (1.0 / (1.0 + (-x).exp()))
}

/// Rows `[row0, row0 + orows.len()/m)` of `a [n,k] @ b [k,m]`, written
/// into `orows` (zero-initialized by the caller). The shared loop body
/// of [`matmul`] / [`matmul_par`]: k is tiled in ascending [`K_BLOCK`]s
/// and zero `a` entries skip their row of `b` exactly like the
/// reference loop, so bits match it for any chunking. The `m`-wide
/// axpy step is element-wise, so its vector form ([`simd::axpy`]) is
/// bit-identical to the scalar loop on every tier.
fn matmul_rows(
    tier: SimdTier,
    a: &[f32],
    b: &[f32],
    k: usize,
    m: usize,
    row0: usize,
    orows: &mut [f32],
) {
    let rows = orows.len() / m;
    let mut kb = 0;
    while kb < k {
        let kend = (kb + K_BLOCK).min(k);
        for r in 0..rows {
            let arow = &a[(row0 + r) * k..(row0 + r) * k + k];
            let orow = &mut orows[r * m..(r + 1) * m];
            for kk in kb..kend {
                let av = arow[kk];
                if av == 0.0 {
                    continue;
                }
                simd::axpy(tier, orow, av, &b[kk * m..(kk + 1) * m]);
            }
        }
        kb = kend;
    }
}

/// Row-major matmul: `a [n, k] @ b [k, m] -> [n, m]`.
///
/// ```
/// use dtrnet::runtime::cpu::kernels::matmul;
/// // [1, 2] @ [2, 1]: 1*3 + 2*4 = 11
/// assert_eq!(matmul(&[1.0, 2.0], &[3.0, 4.0], 1, 2, 1), vec![11.0]);
/// ```
pub fn matmul(a: &[f32], b: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
    matmul_par(&Pool::serial(), a, b, n, k, m)
}

/// [`matmul`] over `pool`: multi-row inputs parallelize across row
/// chunks, a single-row input (the decode hot path) across column
/// chunks. Bit-identical to the serial kernel for any thread count.
pub fn matmul_par(pool: &Pool, a: &[f32], b: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(b.len(), k * m);
    let tier = pool.kernel_ctx().tier;
    let mut out = vec![0.0f32; n * m];
    let work = n * k * m;
    if pool.threads() == 1 || work < PAR_MIN_FLOPS {
        matmul_rows(tier, a, b, k, m, 0, &mut out);
        return out;
    }
    if n == 1 {
        // One output row: chunk its columns (contiguous sub-slices).
        // Accumulation per element is still ascending k.
        let grain = (PAR_CHUNK_FLOPS / k.max(1)).max(16);
        pool.run_rows(&mut out, 1, grain, |c0, ocols| {
            for (kk, &av) in a.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                simd::axpy(tier, ocols, av, &b[kk * m + c0..kk * m + c0 + ocols.len()]);
            }
        });
        return out;
    }
    let grain = (PAR_CHUNK_FLOPS / (k * m).max(1)).max(1);
    pool.run_rows(&mut out, m, grain, |row0, orows| {
        matmul_rows(tier, a, b, k, m, row0, orows)
    });
    out
}

/// Dot product of two equal-length slices.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(&x, &y)| x * y).sum()
}

/// f32 × i8 dot product — the core of [`matmul_q8`]. Accumulates in the
/// fixed 8-lane striped order defined by [`simd::dot_q8_scalar`] (lane
/// partial sums + sequential tail + a pinned reduction tree), which is
/// exactly the order the AVX2/NEON paths compute — so the result is
/// **bit-identical on every SIMD tier**, and `--simd` never changes the
/// int8 backend's output. Dispatches on the process-wide tier; pooled
/// callers ([`matmul_q8_par`]) thread their own pool's tier instead.
#[inline]
pub fn dot_q8(a: &[f32], q: &[i8]) -> f32 {
    simd::dot_q8(crate::util::simd::tier(), a, q)
}

/// Per-output-row symmetric int8 quantization of a weight matrix `w`
/// (row-major `[k, m]`, the [`matmul`] layout). Output channel `j` gets
/// `scale[j] = max|w[:, j]| / 127` and its column is stored as the
/// contiguous i8 row `q[j*k .. (j+1)*k]` — transposed, so the
/// [`matmul_q8`] inner dot walks both operands sequentially. Degenerate
/// output rows are pinned to a safe scale: all-zero columns get scale
/// 1.0 (they quantize to zeros either way), and a subnormal `amax` —
/// where `amax / 127` would round to 0.0 and poison the dequant with
/// inf/NaN — is clamped up to `f32::MIN_POSITIVE`, so every scale is a
/// strictly positive normal number (pinned by the degenerate-row unit
/// test below). Returns `(q, scales)` with `q.len() == m * k`,
/// `scales.len() == m`.
pub fn quantize_rows(w: &[f32], k: usize, m: usize) -> (Vec<i8>, Vec<f32>) {
    debug_assert_eq!(w.len(), k * m);
    let mut scales = vec![0.0f32; m];
    for (j, s) in scales.iter_mut().enumerate() {
        let mut amax = 0.0f32;
        for kk in 0..k {
            amax = amax.max(w[kk * m + j].abs());
        }
        *s = if amax > 0.0 {
            (amax / 127.0).max(f32::MIN_POSITIVE)
        } else {
            1.0
        };
    }
    let mut q = vec![0i8; m * k];
    for j in 0..m {
        let s = scales[j];
        let qrow = &mut q[j * k..(j + 1) * k];
        for (kk, qv) in qrow.iter_mut().enumerate() {
            *qv = (w[kk * m + j] / s).round().clamp(-127.0, 127.0) as i8;
        }
    }
    (q, scales)
}

/// Quantized matmul: `a [n, k] (f32) @ Wq -> [n, m]`, where `Wq` is the
/// `(q, scales)` pair from [`quantize_rows`] (`q` stored `[m, k]`
/// output-row-major). Each output element is one [`dot_q8`] (the fixed
/// striped f32 accumulation, bit-identical on every SIMD tier) scaled
/// once by its row scale — no dequantized copy of the weights ever
/// materializes.
pub fn matmul_q8(a: &[f32], q: &[i8], scales: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
    matmul_q8_par(&Pool::serial(), a, q, scales, n, k, m)
}

/// Rows `[row0, row0 + orows.len()/m)` of [`matmul_q8`], written into
/// `orows` — the shared loop body of the serial and pooled forms.
fn matmul_q8_rows(
    tier: SimdTier,
    a: &[f32],
    q: &[i8],
    scales: &[f32],
    k: usize,
    m: usize,
    row0: usize,
    orows: &mut [f32],
) {
    let rows = orows.len() / m;
    for r in 0..rows {
        let arow = &a[(row0 + r) * k..(row0 + r + 1) * k];
        let orow = &mut orows[r * m..(r + 1) * m];
        for (j, o) in orow.iter_mut().enumerate() {
            *o = simd::dot_q8(tier, arow, &q[j * k..(j + 1) * k]) * scales[j];
        }
    }
}

/// [`matmul_q8`] over `pool`: multi-row inputs parallelize across output
/// row chunks, a single-row input (the decode hot path) across output
/// column chunks. Every output element is computed whole inside one
/// chunk with its serial accumulation order, so the pooled form is
/// bit-identical to the serial kernel for any thread count — the same
/// discipline as [`matmul_par`].
pub fn matmul_q8_par(
    pool: &Pool,
    a: &[f32],
    q: &[i8],
    scales: &[f32],
    n: usize,
    k: usize,
    m: usize,
) -> Vec<f32> {
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(q.len(), m * k);
    debug_assert_eq!(scales.len(), m);
    let tier = pool.kernel_ctx().tier;
    let mut out = vec![0.0f32; n * m];
    let work = n * k * m;
    if pool.threads() == 1 || work < PAR_MIN_FLOPS {
        matmul_q8_rows(tier, a, q, scales, k, m, 0, &mut out);
        return out;
    }
    if n == 1 {
        // One output row: chunk its columns; column j's dot is
        // self-contained, so chunking cannot change any bit.
        let grain = (PAR_CHUNK_FLOPS / k.max(1)).max(16);
        pool.run_rows(&mut out, 1, grain, |c0, ocols| {
            for (t, o) in ocols.iter_mut().enumerate() {
                let j = c0 + t;
                *o = simd::dot_q8(tier, a, &q[j * k..(j + 1) * k]) * scales[j];
            }
        });
        return out;
    }
    let grain = (PAR_CHUNK_FLOPS / (k * m).max(1)).max(1);
    pool.run_rows(&mut out, m, grain, |row0, orows| {
        matmul_q8_rows(tier, a, q, scales, k, m, row0, orows)
    });
    out
}

/// RMSNorm (ref.rmsnorm_ref): `x [n, d]`, `weight [d]`.
pub fn rmsnorm(x: &[f32], weight: &[f32], eps: f32) -> Vec<f32> {
    rmsnorm_par(&Pool::serial(), x, weight, eps)
}

/// [`rmsnorm`] parallelized across row chunks (rows are independent).
/// The variance reduction runs through [`simd::sum_sq`]: sequential
/// order under exact precision (tier-invariant bits), striped/vector
/// under `--precision fast`.
pub fn rmsnorm_par(pool: &Pool, x: &[f32], weight: &[f32], eps: f32) -> Vec<f32> {
    let ctx = pool.kernel_ctx();
    let d = weight.len();
    let n = x.len() / d;
    let mut out = vec![0.0f32; n * d];
    let grain = (PAR_CHUNK_FLOPS / (3 * d).max(1)).max(4);
    pool.run_rows(&mut out, d, grain, |row0, rows| {
        for (r, orow) in rows.chunks_mut(d).enumerate() {
            let row = &x[(row0 + r) * d..(row0 + r + 1) * d];
            let var: f32 = simd::sum_sq(ctx, row) / d as f32;
            let inv = 1.0 / (var + eps).sqrt();
            for j in 0..d {
                orow[j] = row[j] * inv * weight[j];
            }
        }
    });
    out
}

/// DTRNet token router (ref.router_ref, paper Eq. 1):
/// `G = softmax(SiLU(x W1) W2)`. `x [n, d]`, `w1 [d, dh]`, `w2 [dh, 2]`.
/// Returns `[n, 2]` — column 0 = attention path, 1 = bypass.
pub fn router(x: &[f32], w1: &[f32], w2: &[f32], n: usize, d: usize, dh: usize) -> Vec<f32> {
    router_par(&Pool::serial(), x, w1, w2, n, d, dh)
}

/// [`router`] with pooled matmuls and a row-parallel SiLU.
pub fn router_par(
    pool: &Pool,
    x: &[f32],
    w1: &[f32],
    w2: &[f32],
    n: usize,
    d: usize,
    dh: usize,
) -> Vec<f32> {
    let mut hidden = matmul_par(pool, x, w1, n, d, dh);
    let grain = (PAR_CHUNK_FLOPS / (8 * dh).max(1)).max(4);
    pool.run_rows(&mut hidden, dh, grain, |_, rows| {
        for v in rows.iter_mut() {
            *v = silu(*v);
        }
    });
    let mut g = matmul_par(pool, &hidden, w2, n, dh, 2);
    for i in 0..n {
        let m = g[i * 2].max(g[i * 2 + 1]);
        let e0 = (g[i * 2] - m).exp();
        let e1 = (g[i * 2 + 1] - m).exp();
        let z = e0 + e1;
        g[i * 2] = e0 / z;
        g[i * 2 + 1] = e1 / z;
    }
    g
}

/// Hard token-choice routing (ref.route_decision_ref, paper Eq. 2):
/// `delta_i = 1[g_attn > g_bypass]`. `g [n, 2]` -> `[n]` in {0, 1}.
pub fn route_decision(g: &[f32]) -> Vec<f32> {
    let n = g.len() / 2;
    (0..n)
        .map(|i| if g[i * 2] > g[i * 2 + 1] { 1.0 } else { 0.0 })
        .collect()
}

/// Expert-choice top-k mask: exactly `k` ones at the positions of the `k`
/// largest scores (ties broken toward the lower index, deterministically).
pub fn topk_mask(scores: &[f32], k: usize) -> Vec<f32> {
    let n = scores.len();
    let k = k.min(n);
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| {
        scores[b]
            .partial_cmp(&scores[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut mask = vec![0.0f32; n];
    for &i in &idx[..k] {
        mask[i] = 1.0;
    }
    mask
}

/// Linear-path update (ref.bypass_ref, paper Eq. 5 core): `x W^V W^O` —
/// self-attention without interaction. `x [n, d]`, `wv`/`wo` `[d, d]`.
pub fn bypass(x: &[f32], wv: &[f32], wo: &[f32], n: usize, d: usize) -> Vec<f32> {
    bypass_par(&Pool::serial(), x, wv, wo, n, d)
}

/// [`bypass`] with pooled matmuls.
pub fn bypass_par(pool: &Pool, x: &[f32], wv: &[f32], wo: &[f32], n: usize, d: usize) -> Vec<f32> {
    let v = matmul_par(pool, x, wv, n, d, d);
    matmul_par(pool, &v, wo, n, d, d)
}

/// Rotary position embedding (ref.rope_ref) over `x [n, h, hd]` at
/// (possibly fractional, for YaRN-style scaling) `positions [n]`.
pub fn rope(x: &[f32], positions: &[f32], n: usize, h: usize, hd: usize, theta: f32) -> Vec<f32> {
    rope_par(&Pool::serial(), x, positions, n, h, hd, theta)
}

/// [`rope`] parallelized across token rows (rows are independent).
pub fn rope_par(
    pool: &Pool,
    x: &[f32],
    positions: &[f32],
    n: usize,
    h: usize,
    hd: usize,
    theta: f32,
) -> Vec<f32> {
    debug_assert_eq!(x.len(), n * h * hd);
    debug_assert_eq!(positions.len(), n);
    let half = hd / 2;
    let freqs: Vec<f32> = (0..half)
        .map(|j| 1.0 / theta.powf(j as f32 / half as f32))
        .collect();
    let width = h * hd;
    let mut out = vec![0.0f32; n * width];
    // sin_cos dominates; weight the grain accordingly
    let grain = (PAR_CHUNK_FLOPS / (16 * width).max(1)).max(2);
    pool.run_rows(&mut out, width, grain, |row0, rows| {
        for (r, orow) in rows.chunks_mut(width).enumerate() {
            let i = row0 + r;
            for head in 0..h {
                let base = (i * h + head) * hd;
                let obase = head * hd;
                for j in 0..half {
                    let angle = positions[i] * freqs[j];
                    let (sin, cos) = angle.sin_cos();
                    let x1 = x[base + j];
                    let x2 = x[base + half + j];
                    orow[obase + j] = x1 * cos - x2 * sin;
                    orow[obase + half + j] = x1 * sin + x2 * cos;
                }
            }
        }
    });
    out
}

/// Routed multi-head causal attention (ref.routed_attention_ref, paper
/// Eq. 4 + sparse-equivalence Eq. 6). `q`/`k`/`v [n, h, hd]` (q/k already
/// RoPE'd), `delta [n]` in {0, 1}. Attention is causal AND restricted to
/// the routed-token submask `delta·deltaᵀ`; the diagonal is always
/// allowed so every softmax row stays finite (non-routed queries' outputs
/// are discarded by the caller's path select). Returns `[n, h, hd]`.
pub fn routed_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    delta: &[f32],
    n: usize,
    h: usize,
    hd: usize,
) -> Vec<f32> {
    routed_attention_par(&Pool::serial(), q, k, v, delta, n, h, hd)
}

/// [`routed_attention`] parallelized across query rows. Each `(i, head)`
/// output block is self-contained (own logits scratch, own softmax), so
/// chunking the query dimension cannot change any bit.
#[allow(clippy::too_many_arguments)]
pub fn routed_attention_par(
    pool: &Pool,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    delta: &[f32],
    n: usize,
    h: usize,
    hd: usize,
) -> Vec<f32> {
    let ctx = pool.kernel_ctx();
    let scale = 1.0 / (hd as f32).sqrt();
    let width = h * hd;
    let mut out = vec![0.0f32; n * width];
    // Average causal row touches n/2 keys; grain in query rows.
    let per_row = n.div_ceil(2).max(1) * width * 2;
    let grain = (PAR_CHUNK_FLOPS / per_row.max(1)).max(1);
    pool.run_rows(&mut out, width, grain, |i0, rows| {
        let mut logits = vec![0.0f32; n];
        for (r, orow_all) in rows.chunks_mut(width).enumerate() {
            let i = i0 + r;
            for head in 0..h {
                let qi = &q[(i * h + head) * hd..(i * h + head + 1) * hd];
                let row = &mut logits[..i + 1];
                for (j, lg) in row.iter_mut().enumerate() {
                    let allowed = j == i || (delta[i] > 0.5 && delta[j] > 0.5);
                    *lg = if allowed {
                        let kj = &k[(j * h + head) * hd..(j * h + head + 1) * hd];
                        simd::dot_f32(ctx, qi, kj) * scale
                    } else {
                        NEG_INF
                    };
                }
                let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let mut z = 0.0f32;
                for lg in row.iter_mut() {
                    *lg = (*lg - m).exp();
                    z += *lg;
                }
                let orow = &mut orow_all[head * hd..(head + 1) * hd];
                for (j, &w) in row.iter().enumerate() {
                    if w == 0.0 {
                        continue;
                    }
                    let wj = w / z;
                    let vj = &v[(j * h + head) * hd..(j * h + head + 1) * hd];
                    simd::axpy(ctx.tier, orow, wj, vj);
                }
            }
        }
    });
    out
}

/// Plain causal MHA (ref.dense_attention_ref): routed with all-ones delta.
pub fn dense_attention(q: &[f32], k: &[f32], v: &[f32], n: usize, h: usize, hd: usize) -> Vec<f32> {
    let ones = vec![1.0f32; n];
    routed_attention(q, k, v, &ones, n, h, hd)
}

/// [`dense_attention`] over `pool`.
pub fn dense_attention_par(
    pool: &Pool,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    h: usize,
    hd: usize,
) -> Vec<f32> {
    let ones = vec![1.0f32; n];
    routed_attention_par(pool, q, k, v, &ones, n, h, hd)
}

/// Single-query attention over a KV cache plus the current token — the
/// decode-path form of [`routed_attention`]. `q`/`k_self`/`v_self` are
/// `[h*hd]` for the current token (q/k RoPE'd at its absolute position);
/// `cache_k`/`cache_v` are `[len, h*hd]` rows in append order (ascending
/// positions, so the softmax accumulation order matches the batched
/// kernel). Returns `[h*hd]` context.
pub fn decode_attention(
    q: &[f32],
    cache_k: &[f32],
    cache_v: &[f32],
    k_self: &[f32],
    v_self: &[f32],
    h: usize,
    hd: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; h * hd];
    decode_attention_pending(
        KernelCtx::current(),
        q,
        cache_k,
        cache_v,
        &[],
        &[],
        &[],
        k_self,
        v_self,
        h,
        hd,
        &mut out,
    );
    out
}

/// [`decode_attention`] generalized with a *pending* segment: attend the
/// cache rows, then rows `pending` of the not-yet-appended chunk K/V
/// (`pend_k`/`pend_v`, `[chunk, h*hd]`), then the token itself — exactly
/// the key order a sequential decode loop would have seen after
/// appending the pending rows. This is what lets a prefill chunk's rows
/// run concurrently (each row reads the chunk K/V of its predecessors
/// instead of waiting for their cache appends) while producing the same
/// bits as the sequential loop. Accumulates into `out` (`[h*hd]`,
/// zeroed by the caller). `ctx` selects the SIMD tier/precision (pooled
/// callers pass their pool's context; [`decode_attention`] uses the
/// process-wide selection).
#[allow(clippy::too_many_arguments)]
pub fn decode_attention_pending(
    ctx: KernelCtx,
    q: &[f32],
    cache_k: &[f32],
    cache_v: &[f32],
    pend_k: &[f32],
    pend_v: &[f32],
    pending: &[usize],
    k_self: &[f32],
    v_self: &[f32],
    h: usize,
    hd: usize,
    out: &mut [f32],
) {
    let page = [KvPageRef {
        k: cache_k,
        v: cache_v,
    }];
    decode_attention_paged(
        ctx, q, &page, pend_k, pend_v, pending, k_self, v_self, h, hd, out,
    );
}

/// **The canonical cache-read kernel**: [`decode_attention_pending`]
/// over the page-view API — `cache` is the layer's [`KvPageRef`] list
/// from [`crate::runtime::KvCache::view`] (pages in append order,
/// concatenating to the flat slab). Logits are folded page-by-page,
/// row-by-row into one softmax in exactly the flat kernel's key order,
/// so the result is **bit-identical** to [`decode_attention_pending`]
/// on the concatenated rows for any page geometry — the determinism
/// contract that lets the bounded/spilling cache keep token streams
/// bitwise equal to the resident slab (DESIGN.md §KV paging).
#[allow(clippy::too_many_arguments)]
pub fn decode_attention_paged(
    ctx: KernelCtx,
    q: &[f32],
    cache: &[KvPageRef<'_>],
    pend_k: &[f32],
    pend_v: &[f32],
    pending: &[usize],
    k_self: &[f32],
    v_self: &[f32],
    h: usize,
    hd: usize,
    out: &mut [f32],
) {
    let d = h * hd;
    let len: usize = cache.iter().map(|pg| pg.k.len() / d).sum();
    let p = pending.len();
    let scale = 1.0 / (hd as f32).sqrt();
    let mut logits = vec![0.0f32; len + p + 1];
    for head in 0..h {
        let qh = &q[head * hd..(head + 1) * hd];
        let mut j = 0usize;
        for pg in cache {
            for r in 0..pg.k.len() / d {
                let kj = &pg.k[r * d + head * hd..r * d + (head + 1) * hd];
                logits[j] = simd::dot_f32(ctx, qh, kj) * scale;
                j += 1;
            }
        }
        for (t, &pj) in pending.iter().enumerate() {
            let kj = &pend_k[pj * d + head * hd..pj * d + (head + 1) * hd];
            logits[len + t] = simd::dot_f32(ctx, qh, kj) * scale;
        }
        logits[len + p] = simd::dot_f32(ctx, qh, &k_self[head * hd..(head + 1) * hd]) * scale;
        let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for lg in logits.iter_mut() {
            *lg = (*lg - m).exp();
            z += *lg;
        }
        let orow = &mut out[head * hd..(head + 1) * hd];
        let mut j = 0usize;
        for pg in cache {
            for r in 0..pg.v.len() / d {
                let wj = logits[j] / z;
                let vj = &pg.v[r * d + head * hd..r * d + (head + 1) * hd];
                simd::axpy(ctx.tier, orow, wj, vj);
                j += 1;
            }
        }
        for t in 0..p {
            let wj = logits[len + t] / z;
            let pj = pending[t];
            let vj = &pend_v[pj * d + head * hd..pj * d + (head + 1) * hd];
            simd::axpy(ctx.tier, orow, wj, vj);
        }
        let wj = logits[len + p] / z;
        simd::axpy(ctx.tier, orow, wj, &v_self[head * hd..(head + 1) * hd]);
    }
}

/// Gather rows `idx` of `x [n, d]` into a contiguous `[idx.len(), d]`
/// buffer — the routed/bypassed token split of the batched decode path.
pub fn gather_rows(x: &[f32], idx: &[usize], d: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(idx.len() * d);
    for &i in idx {
        out.extend_from_slice(&x[i * d..(i + 1) * d]);
    }
    out
}

/// Scatter `src [idx.len(), d]` rows back into `dst [n, d]` at `idx`,
/// scaling row r by `scale[r]` (the soft router score of the taken path).
pub fn scatter_rows_scaled(dst: &mut [f32], src: &[f32], idx: &[usize], scale: &[f32], d: usize) {
    debug_assert_eq!(src.len(), idx.len() * d);
    debug_assert_eq!(scale.len(), idx.len());
    for (r, &i) in idx.iter().enumerate() {
        let srow = &src[r * d..(r + 1) * d];
        let drow = &mut dst[i * d..(i + 1) * d];
        for (o, &s) in drow.iter_mut().zip(srow) {
            *o = scale[r] * s;
        }
    }
}

/// SwiGLU MLP (ref.swiglu_mlp_ref): `(SiLU(x Wg) * (x Wu)) Wd`.
/// `x [n, d]`, `w_gate`/`w_up [d, ff]`, `w_down [ff, d]`.
pub fn swiglu_mlp(
    x: &[f32],
    w_gate: &[f32],
    w_up: &[f32],
    w_down: &[f32],
    n: usize,
    d: usize,
    ff: usize,
) -> Vec<f32> {
    swiglu_mlp_par(&Pool::serial(), x, w_gate, w_up, w_down, n, d, ff)
}

/// [`swiglu_mlp`] with pooled matmuls and a row-parallel gate fuse.
#[allow(clippy::too_many_arguments)]
pub fn swiglu_mlp_par(
    pool: &Pool,
    x: &[f32],
    w_gate: &[f32],
    w_up: &[f32],
    w_down: &[f32],
    n: usize,
    d: usize,
    ff: usize,
) -> Vec<f32> {
    let mut gate = matmul_par(pool, x, w_gate, n, d, ff);
    let up = matmul_par(pool, x, w_up, n, d, ff);
    let grain = (PAR_CHUNK_FLOPS / (8 * ff).max(1)).max(2);
    pool.run_rows(&mut gate, ff, grain, |row0, rows| {
        let base = row0 * ff;
        for (t, g) in rows.iter_mut().enumerate() {
            *g = silu(*g) * up[base + t];
        }
    });
    matmul_par(pool, &gate, w_down, n, ff, d)
}

/// Q/K/V projection + RoPE on q and k (model.py `_attention_kv` front
/// half). `u [n, d]` normalized stream; returns `(q, k, v)` each
/// `[n, h, hd]` with q/k rotated at `positions`.
#[allow(clippy::too_many_arguments)]
pub fn qkv_rope(
    u: &[f32],
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    positions: &[f32],
    n: usize,
    d: usize,
    h: usize,
    theta: f32,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    qkv_rope_par(&Pool::serial(), u, wq, wk, wv, positions, n, d, h, theta)
}

/// [`qkv_rope`] with pooled projections and rotation.
#[allow(clippy::too_many_arguments)]
pub fn qkv_rope_par(
    pool: &Pool,
    u: &[f32],
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    positions: &[f32],
    n: usize,
    d: usize,
    h: usize,
    theta: f32,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let hd = d / h;
    let q = rope_par(pool, &matmul_par(pool, u, wq, n, d, d), positions, n, h, hd, theta);
    let k = rope_par(pool, &matmul_par(pool, u, wk, n, d, d), positions, n, h, hd, theta);
    let v = matmul_par(pool, u, wv, n, d, d);
    (q, k, v)
}

/// Output of [`dtr_token_update`].
pub struct DtrUpdate {
    /// `[n, d]` token-mixing update (added to the residual stream).
    pub update: Vec<f32>,
    /// `[n, 2]` soft router scores.
    pub g: Vec<f32>,
    /// `[n]` hard routing decisions actually applied.
    pub delta: Vec<f32>,
}

/// Post-router half of the DTR sublayer: given precomputed scores `g`
/// `[n, 2]` and hard decisions `delta` `[n]`, compute the token-mixing
/// update — routed attention for selected tokens, linear bypass for the
/// rest, soft-score path select (paper Eqs. 3–5). Shared by
/// [`dtr_token_update`] (the golden-tested oracle mirror) and the CPU
/// backend's forward path, so both stay under one implementation.
#[allow(clippy::too_many_arguments)]
pub fn dtr_token_mix(
    x: &[f32],
    g: &[f32],
    delta: &[f32],
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    wo: &[f32],
    positions: &[f32],
    n: usize,
    d: usize,
    h: usize,
    theta: f32,
    bypass_vo: bool,
) -> Vec<f32> {
    dtr_token_mix_par(
        &Pool::serial(),
        x,
        g,
        delta,
        wq,
        wk,
        wv,
        wo,
        positions,
        n,
        d,
        h,
        theta,
        bypass_vo,
    )
}

/// [`dtr_token_mix`] over `pool` — the forward path's parallel form.
#[allow(clippy::too_many_arguments)]
pub fn dtr_token_mix_par(
    pool: &Pool,
    x: &[f32],
    g: &[f32],
    delta: &[f32],
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    wo: &[f32],
    positions: &[f32],
    n: usize,
    d: usize,
    h: usize,
    theta: f32,
    bypass_vo: bool,
) -> Vec<f32> {
    let hd = d / h;
    let (q, k, v) = qkv_rope_par(pool, x, wq, wk, wv, positions, n, d, h, theta);
    let ctx = routed_attention_par(pool, &q, &k, &v, delta, n, h, hd);
    let attn_out = matmul_par(pool, &ctx, wo, n, d, d);
    let byp = if bypass_vo {
        bypass_par(pool, x, wv, wo, n, d)
    } else {
        x.to_vec()
    };
    let mut update = vec![0.0f32; n * d];
    for i in 0..n {
        let (w, src) = if delta[i] > 0.5 {
            (g[i * 2], &attn_out)
        } else {
            (g[i * 2 + 1], &byp)
        };
        for j in 0..d {
            update[i * d + j] = w * src[i * d + j];
        }
    }
    update
}

/// Full DTR token-mixing sublayer (ref.dtr_token_update_ref, paper
/// Eqs. 1–5): router → {routed attention, linear bypass} → soft-score
/// path select. `x` is the *normalized* residual stream `[n, d]`.
/// `forced_delta` overrides the token-choice decision (expert-choice
/// top-k, or all-zeros for the dtr_skip ablation); `None` = Eq. 2.
#[allow(clippy::too_many_arguments)]
pub fn dtr_token_update(
    x: &[f32],
    r_w1: &[f32],
    r_w2: &[f32],
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    wo: &[f32],
    positions: &[f32],
    n: usize,
    d: usize,
    h: usize,
    theta: f32,
    bypass_vo: bool,
    forced_delta: Option<&[f32]>,
) -> DtrUpdate {
    let g = router(x, r_w1, r_w2, n, d, d / 2);
    let delta: Vec<f32> = match forced_delta {
        Some(f) => f.to_vec(),
        None => route_decision(&g),
    };
    let update = dtr_token_mix(
        x, &g, &delta, wq, wk, wv, wo, positions, n, d, h, theta, bypass_vo,
    );
    DtrUpdate { update, g, delta }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::assert_allclose;
    use crate::util::rng::Rng;

    fn randn(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32 * scale).collect()
    }

    #[test]
    fn matmul_identity() {
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // [2, 3]
        let mut eye = vec![0.0f32; 9];
        for i in 0..3 {
            eye[i * 3 + i] = 1.0;
        }
        assert_allclose(&matmul(&x, &eye, 2, 3, 3), &x, 1e-6, 1e-6);
    }

    #[test]
    fn matmul_par_bit_identical_to_serial() {
        let pool = Pool::with_threads(4);
        let mut rng = Rng::new(11);
        // shapes spanning the row-parallel, column-parallel (n == 1),
        // and inline (tiny) paths, with k crossing the K_BLOCK tile
        for (n, k, m) in [(1usize, 200usize, 300usize), (7, 65, 129), (64, 64, 64), (2, 3, 4)] {
            let mut a = randn(&mut rng, n * k, 1.0);
            // exercise the zero-skip path too
            for i in (0..a.len()).step_by(5) {
                a[i] = 0.0;
            }
            let b = randn(&mut rng, k * m, 1.0);
            let serial = matmul(&a, &b, n, k, m);
            let par = matmul_par(&pool, &a, &b, n, k, m);
            assert_eq!(serial, par, "bits diverged at n={n} k={k} m={m}");
        }
    }

    #[test]
    fn matmul_q8_par_bit_identical_to_serial() {
        let pool = Pool::with_threads(4);
        let mut rng = Rng::new(21);
        // spans the column-parallel (n == 1), row-parallel, and inline paths
        for (n, k, m) in [(1usize, 200usize, 300usize), (7, 65, 129), (2, 3, 4)] {
            let w = randn(&mut rng, k * m, 0.3);
            let a = randn(&mut rng, n * k, 1.0);
            let (q, scales) = quantize_rows(&w, k, m);
            let serial = matmul_q8(&a, &q, &scales, n, k, m);
            let par = matmul_q8_par(&pool, &a, &q, &scales, n, k, m);
            assert_eq!(serial, par, "q8 bits diverged at n={n} k={k} m={m}");
        }
    }

    #[test]
    fn quantize_rows_is_exact_on_representable_weights() {
        // Integer multiples of a power-of-two-friendly scale, with one
        // entry pinned at ±127·scale per column, survive the round-trip
        // exactly: scale = amax/127 recovers the constructed scale and
        // every entry dequantizes to its original f32 bits.
        let (k, m) = (8usize, 5usize);
        let levels: [i32; 8] = [-127, -64, -32, 0, 1, 2, 64, 127];
        let mut w = vec![0.0f32; k * m];
        for j in 0..m {
            let s = 0.5 * (j as f32 + 1.0);
            for (kk, &t) in levels.iter().enumerate() {
                w[kk * m + j] = t as f32 * s;
            }
        }
        let (q, scales) = quantize_rows(&w, k, m);
        for j in 0..m {
            assert_eq!(scales[j], 0.5 * (j as f32 + 1.0), "col {j} scale");
            for (kk, &t) in levels.iter().enumerate() {
                assert_eq!(q[j * k + kk], t as i8, "col {j} level {kk}");
                let deq = q[j * k + kk] as f32 * scales[j];
                assert_eq!(deq, w[kk * m + j], "col {j} row {kk}");
            }
        }
    }

    #[test]
    fn quantize_rows_handles_zero_columns() {
        let (k, m) = (4usize, 3usize);
        let mut w = vec![0.0f32; k * m];
        for kk in 0..k {
            w[kk * m + 1] = 0.5; // only column 1 is nonzero
        }
        let (q, scales) = quantize_rows(&w, k, m);
        assert_eq!(scales[0], 1.0);
        assert_eq!(scales[2], 1.0);
        assert!(q[..k].iter().all(|&v| v == 0), "zero column must quantize to zeros");
        let a = vec![1.0f32; k];
        let out = matmul_q8(&a, &q, &scales, 1, k, m);
        assert_eq!(out[0], 0.0);
        assert_eq!(out[2], 0.0);
        assert!((out[1] - 2.0).abs() < 1e-5);
    }

    #[test]
    fn quantize_rows_degenerate_rows_stay_finite() {
        // Degenerate output rows must never produce a zero/NaN scale:
        // all-zero columns pin scale 1.0, and a subnormal amax — where
        // amax/127 would underflow to 0.0 and turn the q = w/s divide
        // into inf — is clamped to f32::MIN_POSITIVE. Locks the
        // round-trip: finite scales, finite dot_q8/matmul_q8 outputs.
        let (k, m) = (4usize, 4usize);
        let mut w = vec![0.0f32; k * m];
        for kk in 0..k {
            w[kk * m] = 1e-43; // subnormal column (f32::MIN_POSITIVE ~ 1.2e-38)
            w[kk * m + 1] = 0.0; // all-zero column
            w[kk * m + 2] = 1e30; // large-magnitude column
            w[kk * m + 3] = -0.0; // negative zero column
        }
        let (q, scales) = quantize_rows(&w, k, m);
        for (j, &s) in scales.iter().enumerate() {
            assert!(s.is_finite() && s > 0.0, "col {j} scale {s} not positive-finite");
            assert!(s >= f32::MIN_POSITIVE, "col {j} scale {s} subnormal");
        }
        assert_eq!(scales[1], 1.0);
        assert_eq!(scales[3], 1.0, "-0.0 column must behave like the zero column");
        assert!(q[k..2 * k].iter().all(|&v| v == 0));
        assert!(q[3 * k..4 * k].iter().all(|&v| v == 0));
        let a = vec![1.0f32; k];
        let out = matmul_q8(&a, &q, &scales, 1, k, m);
        for (j, &o) in out.iter().enumerate() {
            assert!(o.is_finite(), "matmul_q8 col {j} produced {o}");
        }
        assert_eq!(out[1], 0.0);
        assert_eq!(out[3], 0.0);
        for j in 0..m {
            let d = simd::dot_q8(SimdTier::Scalar, &a, &q[j * k..(j + 1) * k]) * scales[j];
            assert!(d.is_finite(), "dot_q8 col {j} produced {d}");
        }
    }

    #[test]
    fn matmul_bits_are_invariant_across_simd_tiers() {
        // Exact precision: the tier is a pure throughput knob — matmul
        // and matmul_q8 produce identical bits on scalar and vector
        // tiers for shapes that stress the remainder loops.
        let mut rng = Rng::new(31);
        let scalar = Pool::serial().with_ctx(KernelCtx::scalar());
        let simd_pool = Pool::serial().with_ctx(KernelCtx::scalar().with_tier(simd::detect()));
        for (n, k, m) in [(1usize, 33usize, 7usize), (5, 17, 9), (4, 64, 24)] {
            let a = randn(&mut rng, n * k, 1.0);
            let b = randn(&mut rng, k * m, 1.0);
            assert_eq!(
                matmul_par(&scalar, &a, &b, n, k, m),
                matmul_par(&simd_pool, &a, &b, n, k, m),
                "matmul bits diverged across tiers at n={n} k={k} m={m}"
            );
            let w = randn(&mut rng, k * m, 0.3);
            let (q, scales) = quantize_rows(&w, k, m);
            assert_eq!(
                matmul_q8_par(&scalar, &a, &q, &scales, n, k, m),
                matmul_q8_par(&simd_pool, &a, &q, &scales, n, k, m),
                "matmul_q8 bits diverged across tiers at n={n} k={k} m={m}"
            );
        }
    }

    #[test]
    fn routing_tie_breaks_identically_across_threads_and_tiers() {
        // Equal router scores must select the same token set no matter
        // the thread count or SIMD tier. route_decision ties break to
        // bypass (strict >); topk_mask ties break toward the lower
        // index; and under exact precision the scores themselves are
        // bit-identical across tiers, so the decisions cannot diverge.
        let g_tied = vec![0.5f32, 0.5, 0.7, 0.3, 0.2, 0.8];
        assert_eq!(route_decision(&g_tied), vec![0.0, 1.0, 0.0]);
        let all_equal = vec![0.25f32; 8];
        assert_eq!(topk_mask(&all_equal, 3), {
            let mut want = vec![0.0f32; 8];
            want[0] = 1.0;
            want[1] = 1.0;
            want[2] = 1.0;
            want
        });
        // End-to-end: router scores → decisions, across pools differing
        // in thread count AND tier, must agree exactly.
        let mut rng = Rng::new(32);
        let (n, d) = (24usize, 16usize);
        let x = randn(&mut rng, n * d, 1.0);
        let w1 = randn(&mut rng, d * (d / 2), 0.4);
        let w2 = randn(&mut rng, (d / 2) * 2, 0.4);
        let pools = [
            Pool::serial().with_ctx(KernelCtx::scalar()),
            Pool::with_threads(4).with_ctx(KernelCtx::scalar()),
            Pool::serial().with_ctx(KernelCtx::scalar().with_tier(simd::detect())),
            Pool::with_threads(3).with_ctx(KernelCtx::scalar().with_tier(simd::detect())),
        ];
        let reference = router_par(&pools[0], &x, &w1, &w2, n, d, d / 2);
        let ref_decision = route_decision(&reference);
        let ref_topk = topk_mask(
            &reference.iter().step_by(2).copied().collect::<Vec<_>>(),
            n / 4,
        );
        for (pi, pool) in pools.iter().enumerate() {
            let g = router_par(pool, &x, &w1, &w2, n, d, d / 2);
            assert_eq!(g, reference, "router bits diverged in pool {pi}");
            assert_eq!(route_decision(&g), ref_decision, "decision diverged in pool {pi}");
            let scores: Vec<f32> = g.iter().step_by(2).copied().collect();
            assert_eq!(topk_mask(&scores, n / 4), ref_topk, "topk diverged in pool {pi}");
        }
    }

    #[test]
    fn router_rows_are_distributions() {
        let mut rng = Rng::new(1);
        let (n, d) = (7, 8);
        let g = router(
            &randn(&mut rng, n * d, 1.0),
            &randn(&mut rng, d * (d / 2), 0.5),
            &randn(&mut rng, (d / 2) * 2, 0.5),
            n,
            d,
            d / 2,
        );
        for i in 0..n {
            let s = g[i * 2] + g[i * 2 + 1];
            assert!((s - 1.0).abs() < 1e-5, "row {i} sums to {s}");
            assert!(g[i * 2] >= 0.0 && g[i * 2 + 1] >= 0.0);
        }
    }

    #[test]
    fn rope_at_position_zero_is_identity() {
        let mut rng = Rng::new(2);
        let (n, h, hd) = (3, 2, 4);
        let x = randn(&mut rng, n * h * hd, 1.0);
        let zeros = vec![0.0f32; n];
        let out = rope(&x, &zeros, n, h, hd, 10000.0);
        assert_allclose(&out, &x, 1e-6, 1e-6);
    }

    #[test]
    fn rope_preserves_norm() {
        let mut rng = Rng::new(3);
        let (n, h, hd) = (4, 2, 8);
        let x = randn(&mut rng, n * h * hd, 1.0);
        let pos: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let out = rope(&x, &pos, n, h, hd, 10000.0);
        let norm = |v: &[f32]| v.iter().map(|&a| (a * a) as f64).sum::<f64>();
        assert!((norm(&x) - norm(&out)).abs() < 1e-3);
    }

    #[test]
    fn single_token_attention_returns_value() {
        let mut rng = Rng::new(4);
        let (h, hd) = (2, 4);
        let q = randn(&mut rng, h * hd, 1.0);
        let k = randn(&mut rng, h * hd, 1.0);
        let v = randn(&mut rng, h * hd, 1.0);
        // n = 1: softmax over the single (diagonal) entry is 1 → output = v
        let out = routed_attention(&q, &k, &v, &[0.0], 1, h, hd);
        assert_allclose(&out, &v, 1e-6, 1e-6);
    }

    #[test]
    fn attention_par_bit_identical_to_serial() {
        let pool = Pool::with_threads(3);
        let mut rng = Rng::new(12);
        let (n, h, hd) = (33, 2, 8);
        let q = randn(&mut rng, n * h * hd, 1.0);
        let k = randn(&mut rng, n * h * hd, 1.0);
        let v = randn(&mut rng, n * h * hd, 1.0);
        let delta: Vec<f32> = (0..n).map(|i| if i % 3 == 0 { 1.0 } else { 0.0 }).collect();
        assert_eq!(
            routed_attention(&q, &k, &v, &delta, n, h, hd),
            routed_attention_par(&pool, &q, &k, &v, &delta, n, h, hd),
        );
        assert_eq!(
            dense_attention(&q, &k, &v, n, h, hd),
            dense_attention_par(&pool, &q, &k, &v, n, h, hd),
        );
    }

    #[test]
    fn decode_attention_matches_batched_last_row() {
        let mut rng = Rng::new(5);
        let (n, h, hd) = (6, 2, 4);
        let d = h * hd;
        let q = randn(&mut rng, n * d, 1.0);
        let k = randn(&mut rng, n * d, 1.0);
        let v = randn(&mut rng, n * d, 1.0);
        let full = dense_attention(&q, &k, &v, n, h, hd);
        // decode view: cache = rows 0..n-1, self = row n-1
        let dec = decode_attention(
            &q[(n - 1) * d..],
            &k[..(n - 1) * d],
            &v[..(n - 1) * d],
            &k[(n - 1) * d..],
            &v[(n - 1) * d..],
            h,
            hd,
        );
        assert_allclose(&dec, &full[(n - 1) * d..], 1e-5, 1e-5);
    }

    #[test]
    fn decode_attention_pending_matches_appended_cache() {
        // Attending (cache ++ pending rows) must be bit-identical to
        // attending a cache that already contains those rows.
        let mut rng = Rng::new(13);
        let (h, hd, len, chunk) = (2usize, 4usize, 5usize, 3usize);
        let d = h * hd;
        let cache_k = randn(&mut rng, len * d, 1.0);
        let cache_v = randn(&mut rng, len * d, 1.0);
        let pend_k = randn(&mut rng, chunk * d, 1.0);
        let pend_v = randn(&mut rng, chunk * d, 1.0);
        let q = randn(&mut rng, d, 1.0);
        let ks = randn(&mut rng, d, 1.0);
        let vs = randn(&mut rng, d, 1.0);
        // pending = first two chunk rows
        let mut out_pending = vec![0.0f32; d];
        decode_attention_pending(
            KernelCtx::current(),
            &q,
            &cache_k,
            &cache_v,
            &pend_k,
            &pend_v,
            &[0, 1],
            &ks,
            &vs,
            h,
            hd,
            &mut out_pending,
        );
        let mut big_k = cache_k.clone();
        big_k.extend_from_slice(&pend_k[..2 * d]);
        let mut big_v = cache_v.clone();
        big_v.extend_from_slice(&pend_v[..2 * d]);
        let out_appended = decode_attention(&q, &big_k, &big_v, &ks, &vs, h, hd);
        assert_eq!(out_pending, out_appended, "pending view changed bits");
    }

    #[test]
    fn topk_mask_exact_count_with_ties() {
        let scores = vec![0.5, 0.9, 0.5, 0.1, 0.9, 0.5];
        let mask = topk_mask(&scores, 3);
        assert_eq!(mask.iter().filter(|&&m| m > 0.5).count(), 3);
        // the two 0.9s always make it; the tie among 0.5s breaks low-index
        assert_eq!(mask[1], 1.0);
        assert_eq!(mask[4], 1.0);
        assert_eq!(mask[0], 1.0);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let d = 3;
        let x: Vec<f32> = (0..12).map(|i| i as f32).collect(); // [4, 3]
        let idx = [2usize, 0];
        let g = gather_rows(&x, &idx, d);
        assert_eq!(g, vec![6.0, 7.0, 8.0, 0.0, 1.0, 2.0]);
        let mut dst = vec![0.0f32; 12];
        scatter_rows_scaled(&mut dst, &g, &idx, &[2.0, 1.0], d);
        assert_eq!(&dst[6..9], &[12.0, 14.0, 16.0]);
        assert_eq!(&dst[0..3], &[0.0, 1.0, 2.0]);
        assert_eq!(&dst[3..6], &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn bypass_is_linear_in_x() {
        let mut rng = Rng::new(6);
        let (n, d) = (3, 8);
        let x = randn(&mut rng, n * d, 1.0);
        let wv = randn(&mut rng, d * d, 0.5);
        let wo = randn(&mut rng, d * d, 0.5);
        let y1 = bypass(&x, &wv, &wo, n, d);
        let x2: Vec<f32> = x.iter().map(|&a| 2.0 * a).collect();
        let y2 = bypass(&x2, &wv, &wo, n, d);
        let y1x2: Vec<f32> = y1.iter().map(|&a| 2.0 * a).collect();
        assert_allclose(&y2, &y1x2, 1e-4, 1e-4);
    }

    #[test]
    fn mlp_and_norm_par_bit_identical_to_serial() {
        let pool = Pool::with_threads(4);
        let mut rng = Rng::new(14);
        let (n, d, ff) = (40, 32, 88);
        let x = randn(&mut rng, n * d, 1.0);
        let wg = randn(&mut rng, d * ff, 0.3);
        let wu = randn(&mut rng, d * ff, 0.3);
        let wd = randn(&mut rng, ff * d, 0.3);
        assert_eq!(
            swiglu_mlp(&x, &wg, &wu, &wd, n, d, ff),
            swiglu_mlp_par(&pool, &x, &wg, &wu, &wd, n, d, ff),
        );
        let w = randn(&mut rng, d, 1.0);
        assert_eq!(rmsnorm(&x, &w, 1e-5), rmsnorm_par(&pool, &x, &w, 1e-5));
        let w1 = randn(&mut rng, d * (d / 2), 0.4);
        let w2 = randn(&mut rng, (d / 2) * 2, 0.4);
        assert_eq!(
            router(&x, &w1, &w2, n, d, d / 2),
            router_par(&pool, &x, &w1, &w2, n, d, d / 2),
        );
    }
}
