//! Hand-derived backward passes for the native CPU kernels — the
//! gradient half of the offline training path (`runtime::train`).
//!
//! Every forward kernel in [`super::kernels`] has its reverse-mode
//! counterpart here: matmul (both operand gradients), RMSNorm, RoPE
//! (transposed rotation), causal dense/routed attention (through the
//! saved softmax probabilities), SwiGLU, the DTR router (softmax-of-two
//! head), the softmax cross-entropy head, and the embedding
//! gather/scatter. The layer-level orchestration (activation stack,
//! straight-through path select, Eq. 7 penalty, AdamW) lives in
//! [`crate::runtime::train`]; this module is pure kernels.
//!
//! # Determinism contract
//!
//! Same discipline as the forward kernels (DESIGN.md §Parallel CPU
//! execution): work is only ever split into **data-disjoint output
//! chunks** on the [`Pool`], and every per-element float accumulation
//! keeps a fixed serial order (ascending contraction index). Gradient
//! reductions that cross rows — `dW = Xᵀ·dY`, attention `dK`/`dV`, the
//! RMSNorm gain gradient — are parallelized over the *output* rows, each
//! accumulated in ascending input-row order by exactly one chunk, so
//! `train_step` is bit-identical for every thread count
//! (property-tested in `rust/tests/properties_backend.rs`; the math is
//! held to finite differences in `rust/tests/grad_check.rs`).

use crate::util::threadpool::Pool;

use super::kernels::{self, dot, silu};

/// Derivative of SiLU: `d/dx [x·σ(x)] = σ(x)·(1 + x·(1 − σ(x)))`.
#[inline]
pub fn dsilu(x: f32) -> f32 {
    let s = 1.0 / (1.0 + (-x).exp());
    s * (1.0 + x * (1.0 - s))
}

/// `dst[i] += src[i]` over `pool` (row-disjoint chunks; used to merge
/// gradient contributions without allocating).
pub fn axpy(pool: &Pool, dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    let grain = kernels::PAR_CHUNK_FLOPS.max(1);
    pool.run_rows(dst, 1, grain, |i0, rows| {
        for (t, d) in rows.iter_mut().enumerate() {
            *d += src[i0 + t];
        }
    });
}

/// Gradient of `Y = A·B` w.r.t. `A`: `dA [n,k] = dY [n,m] · Bᵀ [m,k]`.
/// Row-parallel over `dA` rows; each element is one ascending-`j` dot.
pub fn matmul_bwd_a(pool: &Pool, dy: &[f32], b: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
    debug_assert_eq!(dy.len(), n * m);
    debug_assert_eq!(b.len(), k * m);
    let mut da = vec![0.0f32; n * k];
    let grain = (kernels::PAR_CHUNK_FLOPS / (k * m).max(1)).max(1);
    pool.run_rows(&mut da, k, grain, |row0, rows| {
        for (r, orow) in rows.chunks_mut(k).enumerate() {
            let dyrow = &dy[(row0 + r) * m..(row0 + r + 1) * m];
            for (kk, o) in orow.iter_mut().enumerate() {
                *o = dot(dyrow, &b[kk * m..(kk + 1) * m]);
            }
        }
    });
    da
}

/// Gradient of `Y = A·B` w.r.t. `B`: `dB [k,m] = Aᵀ [k,n] · dY [n,m]`.
/// Row-parallel over `dB` rows (= columns of `A`); each output row
/// accumulates `a[i,kk]·dy[i,:]` in ascending `i` order within exactly
/// one chunk, so the cross-row reduction is bit-deterministic.
pub fn matmul_bwd_b(pool: &Pool, a: &[f32], dy: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(dy.len(), n * m);
    let mut db = vec![0.0f32; k * m];
    let grain = (kernels::PAR_CHUNK_FLOPS / (n * m).max(1)).max(1);
    pool.run_rows(&mut db, m, grain, |row0, rows| {
        for (r, orow) in rows.chunks_mut(m).enumerate() {
            let kk = row0 + r;
            for i in 0..n {
                let av = a[i * k + kk];
                if av == 0.0 {
                    continue;
                }
                let dyrow = &dy[i * m..(i + 1) * m];
                for (o, &dv) in orow.iter_mut().zip(dyrow) {
                    *o += av * dv;
                }
            }
        }
    });
    db
}

/// Backward of [`kernels::rmsnorm`]: given `x [n,d]`, gain `w [d]` and
/// upstream `dy [n,d]`, returns `(dx [n,d], dw [d])`.
///
/// With `inv = 1/sqrt(mean(x²)+eps)` (per row):
/// `dx_j = inv·w_j·dy_j − x_j·inv³/d · Σ_t dy_t·w_t·x_t`,
/// `dw_j = Σ_rows dy_j·x_j·inv`.
pub fn rmsnorm_bwd(
    pool: &Pool,
    x: &[f32],
    w: &[f32],
    dy: &[f32],
    eps: f32,
) -> (Vec<f32>, Vec<f32>) {
    let d = w.len();
    let n = x.len() / d;
    debug_assert_eq!(dy.len(), n * d);
    // Per-row inverse RMS, reused by both output passes.
    let mut inv = vec![0.0f32; n];
    let grain = (kernels::PAR_CHUNK_FLOPS / (3 * d).max(1)).max(4);
    pool.run_rows(&mut inv, 1, grain, |row0, rows| {
        for (r, o) in rows.iter_mut().enumerate() {
            let row = &x[(row0 + r) * d..(row0 + r + 1) * d];
            let var: f32 = row.iter().map(|&v| v * v).sum::<f32>() / d as f32;
            *o = 1.0 / (var + eps).sqrt();
        }
    });
    let mut dx = vec![0.0f32; n * d];
    pool.run_rows(&mut dx, d, grain, |row0, rows| {
        for (r, orow) in rows.chunks_mut(d).enumerate() {
            let i = row0 + r;
            let xrow = &x[i * d..(i + 1) * d];
            let dyrow = &dy[i * d..(i + 1) * d];
            let iv = inv[i];
            let mut s = 0.0f32;
            for j in 0..d {
                s += dyrow[j] * w[j] * xrow[j];
            }
            let c = iv * iv * iv * s / d as f32;
            for j in 0..d {
                orow[j] = iv * w[j] * dyrow[j] - xrow[j] * c;
            }
        }
    });
    // Gain gradient: one output element per column, ascending-row sum.
    let mut dw = vec![0.0f32; d];
    let wgrain = (kernels::PAR_CHUNK_FLOPS / (2 * n).max(1)).max(4);
    pool.run_rows(&mut dw, 1, wgrain, |col0, cols| {
        for (t, o) in cols.iter_mut().enumerate() {
            let j = col0 + t;
            let mut acc = 0.0f32;
            for i in 0..n {
                acc += dy[i * d + j] * x[i * d + j] * inv[i];
            }
            *o = acc;
        }
    });
    (dx, dw)
}

/// Backward of [`kernels::rope`]: the rotation is orthogonal per
/// `(j, j+half)` pair, so the gradient is the transposed rotation —
/// `dx1 = dy1·cos + dy2·sin`, `dx2 = −dy1·sin + dy2·cos`. Same
/// row-parallel layout as the forward kernel.
pub fn rope_bwd(
    pool: &Pool,
    dy: &[f32],
    positions: &[f32],
    n: usize,
    h: usize,
    hd: usize,
    theta: f32,
) -> Vec<f32> {
    debug_assert_eq!(dy.len(), n * h * hd);
    debug_assert_eq!(positions.len(), n);
    let half = hd / 2;
    let freqs: Vec<f32> = (0..half)
        .map(|j| 1.0 / theta.powf(j as f32 / half as f32))
        .collect();
    let width = h * hd;
    let mut out = vec![0.0f32; n * width];
    let grain = (kernels::PAR_CHUNK_FLOPS / (16 * width).max(1)).max(2);
    pool.run_rows(&mut out, width, grain, |row0, rows| {
        for (r, orow) in rows.chunks_mut(width).enumerate() {
            let i = row0 + r;
            for head in 0..h {
                let base = (i * h + head) * hd;
                let obase = head * hd;
                for j in 0..half {
                    let angle = positions[i] * freqs[j];
                    let (sin, cos) = angle.sin_cos();
                    let d1 = dy[base + j];
                    let d2 = dy[base + half + j];
                    orow[obase + j] = d1 * cos + d2 * sin;
                    orow[obase + half + j] = -d1 * sin + d2 * cos;
                }
            }
        }
    });
    out
}

/// Training-path forward of [`kernels::routed_attention`]: same output,
/// but additionally materializes the softmax probabilities
/// `probs [n, h, n]` (`probs[(i·h+head)·n + j]`, zero where masked or
/// `j > i`) that the backward pass consumes. Two row-parallel passes
/// (probabilities, then the value-weighted sum), both query-row
/// disjoint.
pub fn routed_attention_probs(
    pool: &Pool,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    delta: &[f32],
    n: usize,
    h: usize,
    hd: usize,
) -> (Vec<f32>, Vec<f32>) {
    let scale = 1.0 / (hd as f32).sqrt();
    let width = h * hd;
    let mut probs = vec![0.0f32; n * h * n];
    let per_row = n.div_ceil(2).max(1) * width * 2;
    let grain = (kernels::PAR_CHUNK_FLOPS / per_row.max(1)).max(1);
    pool.run_rows(&mut probs, h * n, grain, |i0, rows| {
        for (r, prow_all) in rows.chunks_mut(h * n).enumerate() {
            let i = i0 + r;
            for head in 0..h {
                let qi = &q[(i * h + head) * hd..(i * h + head + 1) * hd];
                let prow = &mut prow_all[head * n..head * n + i + 1];
                for (j, lg) in prow.iter_mut().enumerate() {
                    let allowed = j == i || (delta[i] > 0.5 && delta[j] > 0.5);
                    *lg = if allowed {
                        let kj = &k[(j * h + head) * hd..(j * h + head + 1) * hd];
                        dot(qi, kj) * scale
                    } else {
                        kernels::NEG_INF
                    };
                }
                let m = prow.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let mut z = 0.0f32;
                for lg in prow.iter_mut() {
                    *lg = (*lg - m).exp();
                    z += *lg;
                }
                for lg in prow.iter_mut() {
                    *lg /= z;
                }
            }
        }
    });
    let mut out = vec![0.0f32; n * width];
    pool.run_rows(&mut out, width, grain, |i0, rows| {
        for (r, orow_all) in rows.chunks_mut(width).enumerate() {
            let i = i0 + r;
            for head in 0..h {
                let prow = &probs[(i * h + head) * n..(i * h + head) * n + i + 1];
                let orow = &mut orow_all[head * hd..(head + 1) * hd];
                for (j, &w) in prow.iter().enumerate() {
                    if w == 0.0 {
                        continue;
                    }
                    let vj = &v[(j * h + head) * hd..(j * h + head + 1) * hd];
                    for (o, &vv) in orow.iter_mut().zip(vj) {
                        *o += w * vv;
                    }
                }
            }
        }
    });
    (out, probs)
}

/// Backward of causal (dense or routed) attention through saved `probs`
/// (from [`routed_attention_probs`]): given upstream `dout [n,h,hd]`,
/// returns `(dq, dk, dv)` each `[n,h,hd]`.
///
/// With `p = softmax(l)` and `dp_{ij} = dout_i·v_j`:
/// `dl_{ij} = p_{ij}·(dp_{ij} − Σ_t p_{it}·dp_{it})`, then
/// `dq_i = Σ_j dl_{ij}·k_j·scale`, `dk_j = Σ_i dl_{ij}·q_i·scale`,
/// `dv_j = Σ_i p_{ij}·dout_i`. The mask needs no special handling —
/// masked pairs have `p = 0` and contribute nothing. `dq` is
/// query-row-parallel; `dk`/`dv` are key-row-parallel with ascending-`i`
/// accumulation (each output row owned by one chunk).
pub fn routed_attention_bwd(
    pool: &Pool,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    probs: &[f32],
    dout: &[f32],
    n: usize,
    h: usize,
    hd: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let scale = 1.0 / (hd as f32).sqrt();
    let width = h * hd;
    debug_assert_eq!(probs.len(), n * h * n);
    debug_assert_eq!(dout.len(), n * width);
    let per_row = n.div_ceil(2).max(1) * width * 2;
    let grain = (kernels::PAR_CHUNK_FLOPS / per_row.max(1)).max(1);

    // Σ_t p_{it}·dp_{it} per (query row, head) — the softmax row dot.
    let mut rowdot = vec![0.0f32; n * h];
    pool.run_rows(&mut rowdot, h, grain, |i0, rows| {
        for (r, orow) in rows.chunks_mut(h).enumerate() {
            let i = i0 + r;
            for head in 0..h {
                let di = &dout[(i * h + head) * hd..(i * h + head + 1) * hd];
                let prow = &probs[(i * h + head) * n..(i * h + head) * n + i + 1];
                let mut acc = 0.0f32;
                for (j, &p) in prow.iter().enumerate() {
                    if p == 0.0 {
                        continue;
                    }
                    let vj = &v[(j * h + head) * hd..(j * h + head + 1) * hd];
                    acc += p * dot(di, vj);
                }
                orow[head] = acc;
            }
        }
    });

    let mut dq = vec![0.0f32; n * width];
    pool.run_rows(&mut dq, width, grain, |i0, rows| {
        for (r, orow_all) in rows.chunks_mut(width).enumerate() {
            let i = i0 + r;
            for head in 0..h {
                let di = &dout[(i * h + head) * hd..(i * h + head + 1) * hd];
                let prow = &probs[(i * h + head) * n..(i * h + head) * n + i + 1];
                let rd = rowdot[i * h + head];
                let orow = &mut orow_all[head * hd..(head + 1) * hd];
                for (j, &p) in prow.iter().enumerate() {
                    if p == 0.0 {
                        continue;
                    }
                    let vj = &v[(j * h + head) * hd..(j * h + head + 1) * hd];
                    let dl = p * (dot(di, vj) - rd) * scale;
                    let kj = &k[(j * h + head) * hd..(j * h + head + 1) * hd];
                    for (o, &kv) in orow.iter_mut().zip(kj) {
                        *o += dl * kv;
                    }
                }
            }
        }
    });

    let mut dk = vec![0.0f32; n * width];
    pool.run_rows(&mut dk, width, grain, |j0, rows| {
        for (r, orow_all) in rows.chunks_mut(width).enumerate() {
            let j = j0 + r;
            for head in 0..h {
                let orow = &mut orow_all[head * hd..(head + 1) * hd];
                for i in j..n {
                    let p = probs[(i * h + head) * n + j];
                    if p == 0.0 {
                        continue;
                    }
                    let di = &dout[(i * h + head) * hd..(i * h + head + 1) * hd];
                    let vj = &v[(j * h + head) * hd..(j * h + head + 1) * hd];
                    let dl = p * (dot(di, vj) - rowdot[i * h + head]) * scale;
                    let qi = &q[(i * h + head) * hd..(i * h + head + 1) * hd];
                    for (o, &qv) in orow.iter_mut().zip(qi) {
                        *o += dl * qv;
                    }
                }
            }
        }
    });

    let mut dv = vec![0.0f32; n * width];
    pool.run_rows(&mut dv, width, grain, |j0, rows| {
        for (r, orow_all) in rows.chunks_mut(width).enumerate() {
            let j = j0 + r;
            for head in 0..h {
                let orow = &mut orow_all[head * hd..(head + 1) * hd];
                for i in j..n {
                    let p = probs[(i * h + head) * n + j];
                    if p == 0.0 {
                        continue;
                    }
                    let di = &dout[(i * h + head) * hd..(i * h + head + 1) * hd];
                    for (o, &dd) in orow.iter_mut().zip(di) {
                        *o += p * dd;
                    }
                }
            }
        }
    });
    (dq, dk, dv)
}

/// Gradients of the SwiGLU MLP `y = (SiLU(x·Wg) ⊙ (x·Wu))·Wd` given the
/// saved forward intermediates (`gate_pre = x·Wg`, `up = x·Wu`,
/// `hmid = SiLU(gate_pre)⊙up`). Returns `(dx, dWg, dWu, dWd)`.
#[allow(clippy::too_many_arguments)]
pub fn swiglu_bwd(
    pool: &Pool,
    x: &[f32],
    w_gate: &[f32],
    w_up: &[f32],
    w_down: &[f32],
    gate_pre: &[f32],
    up: &[f32],
    hmid: &[f32],
    dy: &[f32],
    n: usize,
    d: usize,
    ff: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let dwd = matmul_bwd_b(pool, hmid, dy, n, ff, d);
    let dhmid = matmul_bwd_a(pool, dy, w_down, n, ff, d);
    // d_up = dhmid ⊙ SiLU(gate_pre); d_gate_pre = dhmid ⊙ up ⊙ SiLU'(gate_pre)
    let mut dup = vec![0.0f32; n * ff];
    let grain = (kernels::PAR_CHUNK_FLOPS / (8 * ff).max(1)).max(2);
    pool.run_rows(&mut dup, ff, grain, |row0, rows| {
        let base = row0 * ff;
        for (t, o) in rows.iter_mut().enumerate() {
            *o = dhmid[base + t] * silu(gate_pre[base + t]);
        }
    });
    let mut dgate = vec![0.0f32; n * ff];
    pool.run_rows(&mut dgate, ff, grain, |row0, rows| {
        let base = row0 * ff;
        for (t, o) in rows.iter_mut().enumerate() {
            *o = dhmid[base + t] * up[base + t] * dsilu(gate_pre[base + t]);
        }
    });
    let dwg = matmul_bwd_b(pool, x, &dgate, n, d, ff);
    let dwu = matmul_bwd_b(pool, x, &dup, n, d, ff);
    let mut dx = matmul_bwd_a(pool, &dgate, w_gate, n, d, ff);
    let dx_up = matmul_bwd_a(pool, &dup, w_up, n, d, ff);
    axpy(pool, &mut dx, &dx_up);
    (dx, dwg, dwu, dwd)
}

/// Backward of the DTR router (ref.router Eq. 1):
/// `g = softmax(SiLU(u·W1)·W2)` row-wise over 2 logits. Recomputes the
/// hidden activations, applies the softmax Jacobian
/// `dz_c = g_c·(dg_c − Σ_t dg_t·g_t)`, and chains through both matmuls.
/// Returns `(du, dW1, dW2)`.
pub fn router_bwd(
    pool: &Pool,
    u: &[f32],
    w1: &[f32],
    w2: &[f32],
    g: &[f32],
    dg: &[f32],
    n: usize,
    d: usize,
    dh: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    debug_assert_eq!(g.len(), n * 2);
    debug_assert_eq!(dg.len(), n * 2);
    let hp = kernels::matmul_par(pool, u, w1, n, d, dh);
    let mut hh = hp.clone();
    let grain = (kernels::PAR_CHUNK_FLOPS / (8 * dh).max(1)).max(4);
    pool.run_rows(&mut hh, dh, grain, |_, rows| {
        for v in rows.iter_mut() {
            *v = silu(*v);
        }
    });
    // Softmax Jacobian over the 2-way head (rows are independent).
    let mut dz = vec![0.0f32; n * 2];
    pool.run_rows(&mut dz, 2, 64, |row0, rows| {
        for (r, orow) in rows.chunks_mut(2).enumerate() {
            let i = row0 + r;
            let s = dg[i * 2] * g[i * 2] + dg[i * 2 + 1] * g[i * 2 + 1];
            orow[0] = g[i * 2] * (dg[i * 2] - s);
            orow[1] = g[i * 2 + 1] * (dg[i * 2 + 1] - s);
        }
    });
    let dw2 = matmul_bwd_b(pool, &hh, &dz, n, dh, 2);
    let dhh = matmul_bwd_a(pool, &dz, w2, n, dh, 2);
    let mut dhp = vec![0.0f32; n * dh];
    pool.run_rows(&mut dhp, dh, grain, |row0, rows| {
        let base = row0 * dh;
        for (t, o) in rows.iter_mut().enumerate() {
            *o = dhh[base + t] * dsilu(hp[base + t]);
        }
    });
    let dw1 = matmul_bwd_b(pool, u, &dhp, n, d, dh);
    let du = matmul_bwd_a(pool, &dhp, w1, n, d, dh);
    (du, dw1, dw2)
}

/// Next-token cross-entropy over one sequence's logits `[n, V]`
/// (position `t` predicts `tokens[t+1]`), accumulated in f64. Returns
/// the *sum* of per-position losses (the caller divides by the batch
/// target count).
pub fn xent_loss_sum(logits: &[f32], tokens: &[i32], n: usize, v: usize) -> f64 {
    let mut total = 0.0f64;
    for t in 1..n {
        let row = &logits[(t - 1) * v..t * v];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let logz: f64 = row.iter().map(|&x| ((x - m) as f64).exp()).sum::<f64>().ln() + m as f64;
        total += logz - row[tokens[t] as usize] as f64;
    }
    total
}

/// Gradient of the mean next-token cross-entropy w.r.t. one sequence's
/// logits `[n, V]`: `dlogits[t−1] = (softmax(logits[t−1]) − onehot) /
/// count` for `t in 1..n` (`count` = total scored positions across the
/// batch); the last row gets zero. Row-parallel (rows independent).
pub fn xent_bwd(
    pool: &Pool,
    logits: &[f32],
    tokens: &[i32],
    count: usize,
    n: usize,
    v: usize,
) -> Vec<f32> {
    let mut dlogits = vec![0.0f32; n * v];
    let inv = 1.0 / count as f32;
    let grain = (kernels::PAR_CHUNK_FLOPS / (4 * v).max(1)).max(1);
    pool.run_rows(&mut dlogits, v, grain, |row0, rows| {
        for (r, orow) in rows.chunks_mut(v).enumerate() {
            let t = row0 + r;
            if t + 1 >= n {
                continue; // final position predicts nothing
            }
            let row = &logits[t * v..(t + 1) * v];
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0.0f32;
            for (o, &x) in orow.iter_mut().zip(row) {
                *o = (x - m).exp();
                z += *o;
            }
            for o in orow.iter_mut() {
                *o = *o / z * inv;
            }
            orow[tokens[t + 1] as usize] -= inv;
        }
    });
    dlogits
}

/// Backward of the embedding gather: scatter-add each token's stream
/// gradient row into its embedding row. Serial by construction — rows
/// repeat when a token recurs, so the accumulation order (ascending
/// position) is part of the determinism contract.
pub fn embedding_bwd(d_embed: &mut [f32], tokens: &[i32], dx: &[f32], d: usize) {
    for (t, &tok) in tokens.iter().enumerate() {
        let row = &dx[t * d..(t + 1) * d];
        let dst = &mut d_embed[tok as usize * d..(tok as usize + 1) * d];
        for (o, &g) in dst.iter_mut().zip(row) {
            *o += g;
        }
    }
}
