//! Native Rust CPU backend — DTRNet end-to-end with no XLA, no artifacts.
//!
//! Evaluates the same block semantics as `python/compile/model.py`
//! (pre-norm RMSNorm + RoPE + SwiGLU; DTR layers: router → routed
//! attention / linear bypass → soft-score path select) over the host
//! [`Tensor`] type, via the oracle-mirrored kernels in [`kernels`].
//!
//! Supported variants: `dense` and the `dtr_*` family (including
//! `dtr_skip`, whose routers are forced to bypass). The MoD / D-LLM
//! baselines remain PJRT-artifact-only for now.
//!
//! Weights interoperate with the DTCK checkpoint format using the same
//! `flatten_params` naming contract as the Python side
//! (`tok_embed`, `unembed`, `out_norm`, `layers.{i}.{key}`), so a
//! PJRT-trained checkpoint can be served by this backend and vice versa.
//!
//! # Parallel execution
//!
//! Every hot path runs through the pool-aware `_par` kernels in
//! [`kernels`], parallelized across rows/tiles on a
//! [`Pool`](crate::util::threadpool::Pool) (default: the process-wide
//! pool, sized by `--threads` / available parallelism). Parallel
//! execution is **bit-identical** to `--threads 1` — chunks are
//! data-disjoint and every float accumulation keeps its serial order —
//! so thread count is a pure throughput knob, never a semantics knob
//! (property-tested bitwise in `rust/tests/properties_backend.rs`).
//! Per-kernel wall-clock goes to a [`KernelTimers`] readable through
//! [`Backend::kernel_timings`].

pub mod grads;
pub mod kernels;

use anyhow::{bail, ensure, Context, Result};

use crate::config::{LayerKind, ModelConfig, Variant};
use crate::metrics::KernelTimers;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::threadpool::{self, Pool};

use crate::telemetry::FlopCounters;

use super::backend::{
    Backend, DecodeState, ForwardOutput, PrefillRows, RouteOverride, StepOutput,
};
use super::checkpoint::Checkpoint;
use super::tensor::Tensor;

/// RoPE base frequency (model.py `rope_theta` default).
pub const ROPE_THETA: f32 = 10000.0;
/// RMSNorm epsilon (model.py `rmsnorm_eps` default).
pub const RMSNORM_EPS: f32 = 1e-5;

/// How DTR layers turn router scores into hard routing decisions.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RouterMode {
    /// Paper Eq. 2: route token i iff `g_attn > g_bypass` (the default;
    /// causal, so it is the mode decode supports).
    TokenChoice,
    /// Appendix A1 ablation: route exactly `ceil(capacity * n)` tokens —
    /// the top-k by `g_attn` over the full sequence. Forward-only.
    ExpertChoice { capacity: f64 },
}

/// One layer's weights (flat row-major, shapes per model.py init_params).
#[derive(Debug, Clone)]
pub struct LayerWeights {
    /// Block kind this layer was built for (checked against the config).
    pub kind: LayerKind,
    /// Pre-attention RMSNorm gain `[d]`.
    pub norm1: Vec<f32>,  // [d]
    /// Pre-MLP RMSNorm gain `[d]`.
    pub norm2: Vec<f32>,  // [d]
    /// Query projection `[d, d]`.
    pub wq: Vec<f32>,     // [d, d]
    /// Key projection `[d, d]`.
    pub wk: Vec<f32>,     // [d, d]
    /// Value projection `[d, d]`.
    pub wv: Vec<f32>,     // [d, d]
    /// Output projection `[d, d]`.
    pub wo: Vec<f32>,     // [d, d]
    /// SwiGLU gate projection `[d, ff]`.
    pub w_gate: Vec<f32>, // [d, ff]
    /// SwiGLU up projection `[d, ff]`.
    pub w_up: Vec<f32>,   // [d, ff]
    /// SwiGLU down projection `[ff, d]`.
    pub w_down: Vec<f32>, // [ff, d]
    /// Router first layer `[d, d/2]` (empty on dense layers).
    pub r_w1: Vec<f32>,   // [d, d/2] (empty on dense layers)
    /// Router second layer `[d/2, 2]` (empty on dense layers).
    pub r_w2: Vec<f32>,   // [d/2, 2] (empty on dense layers)
}

/// Full parameter set for one model.
#[derive(Debug, Clone)]
pub struct ModelWeights {
    /// Token embedding `[V, d]`.
    pub tok_embed: Vec<f32>, // [V, d]
    /// Unembedding `[d, V]`.
    pub unembed: Vec<f32>,   // [d, V]
    /// Final RMSNorm gain `[d]`.
    pub out_norm: Vec<f32>,  // [d]
    /// Per-layer weights, in layer order.
    pub layers: Vec<LayerWeights>,
}

impl ModelWeights {
    /// A zero-filled parameter set with `cfg`'s shapes — the gradient /
    /// Adam-moment accumulator layout used by the native trainer.
    pub fn zeros_like(cfg: &ModelConfig) -> ModelWeights {
        let (d, ff, v) = (cfg.d_model, cfg.d_ff, cfg.vocab_size);
        let layers = cfg
            .layer_kinds()
            .into_iter()
            .map(|kind| {
                let routed = kind == LayerKind::Dtr;
                LayerWeights {
                    kind,
                    norm1: vec![0.0; d],
                    norm2: vec![0.0; d],
                    wq: vec![0.0; d * d],
                    wk: vec![0.0; d * d],
                    wv: vec![0.0; d * d],
                    wo: vec![0.0; d * d],
                    w_gate: vec![0.0; d * ff],
                    w_up: vec![0.0; d * ff],
                    w_down: vec![0.0; ff * d],
                    r_w1: if routed { vec![0.0; d * (d / 2)] } else { Vec::new() },
                    r_w2: if routed { vec![0.0; (d / 2) * 2] } else { Vec::new() },
                }
            })
            .collect();
        ModelWeights {
            tok_embed: vec![0.0; v * d],
            unembed: vec![0.0; d * v],
            out_norm: vec![0.0; d],
            layers,
        }
    }

    /// Every tensor in a fixed canonical order, with its "is a matrix"
    /// flag (rank ≥ 2 — the AdamW weight-decay criterion; norm gains are
    /// exempt). [`ModelWeights::tensors_mut`] yields the same order, so
    /// params/grads/moments zip positionally.
    pub fn tensors(&self) -> Vec<(&Vec<f32>, bool)> {
        let mut out: Vec<(&Vec<f32>, bool)> = vec![
            (&self.tok_embed, true),
            (&self.unembed, true),
            (&self.out_norm, false),
        ];
        for lw in &self.layers {
            out.push((&lw.norm1, false));
            out.push((&lw.norm2, false));
            out.push((&lw.wq, true));
            out.push((&lw.wk, true));
            out.push((&lw.wv, true));
            out.push((&lw.wo, true));
            out.push((&lw.w_gate, true));
            out.push((&lw.w_up, true));
            out.push((&lw.w_down, true));
            out.push((&lw.r_w1, true));
            out.push((&lw.r_w2, true));
        }
        out
    }

    /// Mutable view of [`ModelWeights::tensors`], same order.
    pub fn tensors_mut(&mut self) -> Vec<(&mut Vec<f32>, bool)> {
        let mut out: Vec<(&mut Vec<f32>, bool)> = vec![
            (&mut self.tok_embed, true),
            (&mut self.unembed, true),
            (&mut self.out_norm, false),
        ];
        for lw in self.layers.iter_mut() {
            let LayerWeights {
                norm1,
                norm2,
                wq,
                wk,
                wv,
                wo,
                w_gate,
                w_up,
                w_down,
                r_w1,
                r_w2,
                ..
            } = lw;
            out.push((norm1, false));
            out.push((norm2, false));
            out.push((wq, true));
            out.push((wk, true));
            out.push((wv, true));
            out.push((wo, true));
            out.push((w_gate, true));
            out.push((w_up, true));
            out.push((w_down, true));
            out.push((r_w1, true));
            out.push((r_w2, true));
        }
        out
    }
}

/// Seeded LLaMA-style random initialization (N(0, 0.02), output
/// projections scaled by 1/sqrt(2L), norms at one), shared by
/// [`CpuBackend::init`] and the native trainer
/// ([`crate::runtime::train::CpuTrainer`]) so `demo`/`serve` at seed `s`
/// and `train` at seed `s` start from the same bits.
pub fn init_weights(cfg: &ModelConfig, seed: u64) -> ModelWeights {
    let (d, ff, v) = (cfg.d_model, cfg.d_ff, cfg.vocab_size);
    let std = 0.02f32;
    let out_std = std / (2.0 * cfg.n_layers as f32).sqrt();
    let mut rng = Rng::new(seed ^ 0xD7121517);
    let mut mat = |n: usize, s: f32| -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32 * s).collect()
    };
    let mut layers = Vec::with_capacity(cfg.n_layers);
    let kinds = cfg.layer_kinds();
    let tok_embed = mat(v * d, std);
    let unembed = mat(d * v, std);
    for kind in kinds {
        let routed = kind == LayerKind::Dtr;
        layers.push(LayerWeights {
            kind,
            norm1: vec![1.0; d],
            norm2: vec![1.0; d],
            wq: mat(d * d, std),
            wk: mat(d * d, std),
            wv: mat(d * d, std),
            wo: mat(d * d, out_std),
            w_gate: mat(d * ff, std),
            w_up: mat(d * ff, std),
            w_down: mat(ff * d, out_std),
            r_w1: if routed { mat(d * (d / 2), std) } else { Vec::new() },
            r_w2: if routed { mat((d / 2) * 2, std) } else { Vec::new() },
        });
    }
    ModelWeights {
        tok_embed,
        unembed,
        out_norm: vec![1.0; d],
        layers,
    }
}

/// Export `weights` as a DTCK checkpoint under the Python
/// `flatten_params` naming/order contract — shared by
/// [`CpuBackend::to_checkpoint`] and the native trainer.
pub fn weights_to_checkpoint(cfg: &ModelConfig, weights: &ModelWeights) -> Checkpoint {
    let (d, ff, v) = (cfg.d_model, cfg.d_ff, cfg.vocab_size);
    let mut ck = Checkpoint::new();
    ck.push("tok_embed", Tensor::f32(vec![v, d], weights.tok_embed.clone()));
    ck.push("unembed", Tensor::f32(vec![d, v], weights.unembed.clone()));
    ck.push("out_norm", Tensor::f32(vec![d], weights.out_norm.clone()));
    for (i, lw) in weights.layers.iter().enumerate() {
        // sorted key order within a layer (flatten_params contract)
        let mut entries: Vec<(&str, Vec<usize>, &Vec<f32>)> = vec![
            ("norm1", vec![d], &lw.norm1),
            ("norm2", vec![d], &lw.norm2),
            ("w_down", vec![ff, d], &lw.w_down),
            ("w_gate", vec![d, ff], &lw.w_gate),
            ("w_up", vec![d, ff], &lw.w_up),
            ("wk", vec![d, d], &lw.wk),
            ("wo", vec![d, d], &lw.wo),
            ("wq", vec![d, d], &lw.wq),
            ("wv", vec![d, d], &lw.wv),
        ];
        if lw.kind == LayerKind::Dtr {
            entries.push(("r_w1", vec![d, d / 2], &lw.r_w1));
            entries.push(("r_w2", vec![d / 2, 2], &lw.r_w2));
        }
        entries.sort_by(|a, b| a.0.cmp(b.0));
        for (name, shape, data) in entries {
            ck.push(format!("layers.{i}.{name}"), Tensor::f32(shape, data.clone()));
        }
    }
    ck
}

/// The native CPU execution backend.
pub struct CpuBackend {
    cfg: ModelConfig,
    weights: ModelWeights,
    router_mode: RouterMode,
    /// Kernel execution pool (default: the process-wide shared pool).
    pool: Pool,
    /// Per-kernel wall-clock accounting, always on (two clock reads per
    /// section per step — negligible next to the matmuls it brackets).
    timers: KernelTimers,
    /// Measured per-layer FLOP accounting, always on (a handful of
    /// relaxed atomic adds per layer per call — negligible next to the
    /// matmuls they count). Reconciled against `model/flops.rs` in tests.
    flops: FlopCounters,
}

/// Which rows of a [`CpuBackend::step_rows`] call need logits. Only the
/// requested rows pay the `[·, V]` unembed matmul — the dominant
/// per-token cost at small `d_model` — so intermediate prefill chunks
/// (whose logits nobody reads) skip it entirely.
#[derive(Clone, Copy, PartialEq)]
enum LogitsRows {
    All,
    Last,
    None,
}

/// Output of [`CpuBackend::step_rows`].
struct RowsOutput {
    /// Logits per [`LogitsRows`]: `[n, V]`, `[1, V]`, or empty.
    logits: Vec<f32>,
    /// `[n][L]` per-row hard routing decisions.
    routed: Vec<Vec<bool>>,
    /// `[n][L]` per-row soft attention scores (1.0 on dense layers).
    g_attn: Vec<Vec<f32>>,
}

/// Attend each row r against layer `li` of `states[rows_cache[r]]` plus
/// the row's own K/V, honoring within-chunk causality: later rows mapped
/// to the same cache see earlier ones, rows mapped to distinct caches
/// are independent. Rows run **concurrently** — instead of waiting for
/// its predecessors' cache appends, each row reads them straight out of
/// the chunk K/V (`kernels::decode_attention_paged` over the cache's
/// page views), which visits keys in exactly the order a sequential
/// attend-then-append loop would have, so the result (and the cache
/// bytes appended afterwards) is bit-identical to that loop. Returns
/// `[m, d]` context rows. Shared with the quantized backend
/// (`runtime::quant`), whose step path is the same modulo projection
/// kernels.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attend_rows(
    pool: &Pool,
    q: &[f32],
    kk: &[f32],
    vv: &[f32],
    states: &mut [&mut DecodeState],
    rows_cache: &[usize],
    li: usize,
    d: usize,
    heads: usize,
    hd: usize,
) -> Vec<f32> {
    let m = rows_cache.len();
    let mut ctx = vec![0.0f32; m * d];
    // Fault every referenced cache's layer-li pages resident (bounded
    // caches evict LRU pages of other layers); resident slabs no-op.
    let mut pinned = vec![false; states.len()];
    for &c in rows_cache {
        if !pinned[c] {
            states[c].kv.pin_layer(li);
            pinned[c] = true;
        }
    }
    {
        // Immutable page-view snapshot of every pinned cache's layer-li
        // K/V for the parallel reads; the appends below wait until all
        // rows finish. Unpinned states get an empty view (never read).
        let views: Vec<Vec<crate::runtime::kv::KvPageRef<'_>>> = states
            .iter()
            .enumerate()
            .map(|(c, st)| if pinned[c] { st.kv.view(li, d) } else { Vec::new() })
            .collect();
        // Chunk rows before r that share r's cache (ascending — the
        // order a sequential loop would have appended them).
        let pending: Vec<Vec<usize>> = (0..m)
            .map(|r| (0..r).filter(|&p| rows_cache[p] == rows_cache[r]).collect())
            .collect();
        let cached_rows: usize = views
            .iter()
            .flat_map(|pages| pages.iter().map(|pg| pg.rows(d)))
            .sum();
        let per_row = (cached_rows / m.max(1) + m / 2 + 1) * d * 2;
        let grain = (kernels::PAR_CHUNK_FLOPS / per_row.max(1)).max(1);
        let kctx = pool.kernel_ctx();
        pool.run_rows(&mut ctx, d, grain, |r0, rows| {
            for (i, orow) in rows.chunks_mut(d).enumerate() {
                let r = r0 + i;
                kernels::decode_attention_paged(
                    kctx,
                    &q[r * d..(r + 1) * d],
                    &views[rows_cache[r]],
                    kk,
                    vv,
                    &pending[r],
                    &kk[r * d..(r + 1) * d],
                    &vv[r * d..(r + 1) * d],
                    heads,
                    hd,
                    orow,
                );
            }
        });
    }
    for (r, &c) in rows_cache.iter().enumerate() {
        states[c]
            .kv
            .append_row(li, &kk[r * d..(r + 1) * d], &vv[r * d..(r + 1) * d]);
    }
    ctx
}

/// Total causal context (keys visited, including each row's own K/V)
/// that [`attend_rows`] will see for these rows at layer `li`: per row,
/// the cache's current length plus earlier chunk rows sharing its cache
/// plus one. Must be computed **before** `attend_rows` appends. Feeds
/// the measured `attn_mix` FLOP count (shared with `runtime::quant`).
pub(crate) fn attend_context_rows(
    states: &[&mut DecodeState],
    rows_cache: &[usize],
    li: usize,
    d: usize,
) -> u64 {
    let mut total = 0u64;
    for (r, &c) in rows_cache.iter().enumerate() {
        let cached = states[c].kv.len(li, d);
        let pending = rows_cache[..r].iter().filter(|&&p| p == c).count();
        total += (cached + pending + 1) as u64;
    }
    total
}

/// Dense-equivalent FLOPs for rows fed at `positions` — what a dense
/// layer would have spent on the same rows: QKVO + attention over the
/// full causal context (position+1 keys) + MLP. The per-layer
/// denominator of the measured FLOPs-vs-dense ratio (the exact per-row
/// form of `model::flops::dense_flops_per_token`; shared with
/// `runtime::quant`).
pub(crate) fn dense_equiv_flops(positions: &[f32], d: usize, ff: usize) -> u64 {
    let (d, ff) = (d as u64, ff as u64);
    positions
        .iter()
        .map(|&p| 8 * d * d + 4 * d * (p as u64 + 1) + 6 * d * ff)
        .sum()
}

/// Validate a (config, weights) pair for native execution: supported
/// variant, valid config, and every tensor at its init_params shape.
/// Shared by [`CpuBackend::new`] and the quantized backend
/// (`runtime::quant`), which quantizes only weights that pass here.
pub(crate) fn validate_weights(cfg: &ModelConfig, weights: &ModelWeights) -> Result<()> {
    ensure!(
        cfg.variant == Variant::Dense || cfg.variant.is_dtr(),
        "CPU backend supports dense/dtr_* variants, not {:?} (MoD/D-LLM are PJRT-only)",
        cfg.variant
    );
    cfg.validate()?;
    let (d, ff, v) = (cfg.d_model, cfg.d_ff, cfg.vocab_size);
    ensure!(weights.tok_embed.len() == v * d, "tok_embed shape");
    ensure!(weights.unembed.len() == d * v, "unembed shape");
    ensure!(weights.out_norm.len() == d, "out_norm shape");
    ensure!(
        weights.layers.len() == cfg.n_layers,
        "expected {} layers, got {}",
        cfg.n_layers,
        weights.layers.len()
    );
    for (i, (lw, kind)) in weights.layers.iter().zip(cfg.layer_kinds()).enumerate() {
        ensure!(lw.kind == kind, "layer {i}: kind mismatch with config layout");
        ensure!(lw.norm1.len() == d && lw.norm2.len() == d, "layer {i}: norm shape");
        ensure!(
            lw.wq.len() == d * d
                && lw.wk.len() == d * d
                && lw.wv.len() == d * d
                && lw.wo.len() == d * d,
            "layer {i}: attention projection shape"
        );
        ensure!(
            lw.w_gate.len() == d * ff && lw.w_up.len() == d * ff && lw.w_down.len() == ff * d,
            "layer {i}: mlp shape"
        );
        match kind {
            LayerKind::Dtr => ensure!(
                lw.r_w1.len() == d * (d / 2) && lw.r_w2.len() == (d / 2) * 2,
                "layer {i}: router shape"
            ),
            LayerKind::Dense => ensure!(
                lw.r_w1.is_empty() && lw.r_w2.is_empty(),
                "layer {i}: dense layer must not carry router weights"
            ),
            _ => bail!("layer {i}: unsupported kind for CPU backend"),
        }
    }
    Ok(())
}

impl CpuBackend {
    /// Build from explicit weights, validating variant support and shapes.
    pub fn new(cfg: ModelConfig, weights: ModelWeights, mode: RouterMode) -> Result<CpuBackend> {
        validate_weights(&cfg, &weights)?;
        let n_layers = cfg.n_layers;
        Ok(CpuBackend {
            cfg,
            weights,
            router_mode: mode,
            pool: threadpool::global().clone(),
            timers: KernelTimers::default(),
            flops: FlopCounters::new(n_layers),
        })
    }

    /// Seeded random initialization (LLaMA-style: N(0, 0.02), output
    /// projections scaled by 1/sqrt(2L), norms at one — mirroring
    /// model.py `init_params`' distributional choices, not its bits).
    ///
    /// ```
    /// use dtrnet::config::{ModelConfig, Variant};
    /// use dtrnet::coordinator::SamplingParams;
    /// use dtrnet::runtime::{Backend, CpuBackend};
    /// use dtrnet::util::rng::Rng;
    ///
    /// let cfg = ModelConfig::preset("xs", Variant::DtrBilayer);
    /// let backend = CpuBackend::init(&cfg, 0).unwrap();
    /// let mut rng = Rng::new(1);
    /// let out = backend
    ///     .generate(&[1, 2, 3], 4, &SamplingParams::greedy(), &mut rng)
    ///     .unwrap();
    /// assert_eq!(out.tokens.len(), 4);
    /// // Dense layers route every token; DTR layers only a fraction.
    /// assert_eq!(out.attn_frac.len(), cfg.n_layers);
    /// ```
    pub fn init(cfg: &ModelConfig, seed: u64) -> Result<CpuBackend> {
        CpuBackend::new(cfg.clone(), init_weights(cfg, seed), RouterMode::TokenChoice)
    }

    /// Switch between token-choice and expert-choice routing.
    pub fn set_router_mode(&mut self, mode: RouterMode) {
        self.router_mode = mode;
    }

    /// The active routing mode.
    pub fn router_mode(&self) -> RouterMode {
        self.router_mode
    }

    /// Run kernels on an explicit pool instead of the process-wide one.
    /// Thread count changes throughput only — outputs are bit-identical
    /// for every pool size.
    pub fn set_pool(&mut self, pool: Pool) {
        self.pool = pool;
    }

    /// Convenience for [`CpuBackend::set_pool`]: a fresh pool of `n`
    /// threads (`1` = the serial determinism baseline).
    pub fn set_threads(&mut self, n: usize) {
        self.pool = Pool::with_threads(n);
    }

    /// Kernel-thread concurrency this backend currently runs with.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Per-kernel wall-clock accounting (always on; reset between bench
    /// scenarios via [`KernelTimers::reset`]).
    pub fn timers(&self) -> &KernelTimers {
        &self.timers
    }

    /// The backend's full-precision parameter set (read-only — the
    /// quantized backend is built from this view).
    pub fn weights(&self) -> &ModelWeights {
        &self.weights
    }

    /// Int8-quantize this backend's weights into a
    /// [`QuantizedCpuBackend`](crate::runtime::quant::QuantizedCpuBackend)
    /// sharing the same config, router mode, and kernel pool (see
    /// DESIGN.md §Quantization).
    pub fn quantized(&self) -> Result<crate::runtime::quant::QuantizedCpuBackend> {
        let mut q = crate::runtime::quant::QuantizedCpuBackend::from_weights(
            &self.cfg,
            &self.weights,
            self.router_mode,
        )?;
        q.set_pool(self.pool.clone());
        Ok(q)
    }

    /// Export weights as a DTCK checkpoint using the Python
    /// `flatten_params` naming/order contract.
    pub fn to_checkpoint(&self) -> Checkpoint {
        weights_to_checkpoint(&self.cfg, &self.weights)
    }

    /// Load weights from a DTCK checkpoint (names per `flatten_params`).
    pub fn from_checkpoint(cfg: &ModelConfig, ck: &Checkpoint) -> Result<CpuBackend> {
        let get = |name: &str| -> Result<Vec<f32>> {
            Ok(ck
                .get(name)
                .with_context(|| format!("checkpoint missing {name}"))?
                .as_f32()
                .to_vec())
        };
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for (i, kind) in cfg.layer_kinds().into_iter().enumerate() {
            let lg = |key: &str| get(&format!("layers.{i}.{key}"));
            let routed = kind == LayerKind::Dtr;
            layers.push(LayerWeights {
                kind,
                norm1: lg("norm1")?,
                norm2: lg("norm2")?,
                wq: lg("wq")?,
                wk: lg("wk")?,
                wv: lg("wv")?,
                wo: lg("wo")?,
                w_gate: lg("w_gate")?,
                w_up: lg("w_up")?,
                w_down: lg("w_down")?,
                r_w1: if routed { lg("r_w1")? } else { Vec::new() },
                r_w2: if routed { lg("r_w2")? } else { Vec::new() },
            });
        }
        let weights = ModelWeights {
            tok_embed: get("tok_embed")?,
            unembed: get("unembed")?,
            out_norm: get("out_norm")?,
            layers,
        };
        CpuBackend::new(cfg.clone(), weights, RouterMode::TokenChoice)
    }

    /// Hard routing decision for one DTR layer over the full sequence.
    fn decide(&self, g: &[f32], n: usize) -> Vec<f32> {
        if self.cfg.variant == Variant::DtrSkip {
            return vec![0.0; n];
        }
        match self.router_mode {
            RouterMode::TokenChoice => kernels::route_decision(g),
            RouterMode::ExpertChoice { capacity } => {
                let g0: Vec<f32> = (0..n).map(|i| g[i * 2]).collect();
                let k = ((capacity * n as f64).ceil() as usize).max(1);
                kernels::topk_mask(&g0, k)
            }
        }
    }

    /// Row-parallel DTRNet step — the shared core of
    /// [`Backend::decode_batch`] and the chunked-prefill path. Each row r
    /// is one token fed at `positions[r]` against the cache
    /// `states[cache_of[r]]`. Rows are processed in order within every
    /// layer, and a row's K/V are appended to its cache before the next
    /// row attends — row order IS causal order: batched decode maps each
    /// row to its own sequence, chunked prefill maps every row to the
    /// same sequence (within-chunk causality). All per-row math runs
    /// through the batched norm/router/projection/MLP kernels, which are
    /// row-independent, so outputs and cache bits are identical to a
    /// sequential [`Backend::decode_step`] loop. `logits` selects which
    /// rows pay the unembed matmul (the prefill fast path). Each row
    /// advances its cache's position by one. `route` is the per-call
    /// routing override: [`RouteOverride::ForceBypass`] pins every DTR
    /// row onto the linear bypass (the speculative draft pass — router
    /// weights still evaluated, their soft score still scales the
    /// bypass update).
    fn step_rows(
        &self,
        toks: &[i32],
        positions: &[f32],
        states: &mut [&mut DecodeState],
        cache_of: &[usize],
        logits: LogitsRows,
        route: RouteOverride,
    ) -> Result<RowsOutput> {
        let cfg = &self.cfg;
        let (d, ff, vocab) = (cfg.d_model, cfg.d_ff, cfg.vocab_size);
        let (heads, hd) = (cfg.n_heads, cfg.head_dim());
        let n = toks.len();
        ensure!(n > 0, "step_rows needs at least one row");
        debug_assert_eq!(positions.len(), n);
        debug_assert_eq!(cache_of.len(), n);
        for &t in toks {
            ensure!(
                t >= 0 && (t as usize) < vocab,
                "token id {t} out of range for vocab {vocab}"
            );
        }
        ensure!(
            !matches!(self.router_mode, RouterMode::ExpertChoice { .. }),
            "expert-choice routing needs the full sequence; incremental \
             decode/prefill supports token-choice only"
        );

        let mut x = Vec::with_capacity(n * d);
        for &t in toks {
            let t = t as usize;
            x.extend_from_slice(&self.weights.tok_embed[t * d..(t + 1) * d]);
        }

        let pool = &self.pool;
        let (du, ffu) = (d as u64, ff as u64);
        let dense_eq = dense_equiv_flops(positions, d, ff);
        let mut routed = vec![Vec::with_capacity(cfg.n_layers); n];
        let mut g_attn = vec![Vec::with_capacity(cfg.n_layers); n];
        for (li, lw) in self.weights.layers.iter().enumerate() {
            self.flops.add_dense_equiv(li, dense_eq);
            let u = self
                .timers
                .norm
                .time(|| kernels::rmsnorm_par(pool, &x, &lw.norm1, RMSNORM_EPS));
            let mut mixed = vec![0.0f32; n * d];
            match lw.kind {
                LayerKind::Dense => {
                    self.flops.add_qkvo(li, n as u64 * 8 * du * du);
                    self.flops.add_attn_mix(
                        li,
                        4 * du * attend_context_rows(states, cache_of, li, d),
                    );
                    mixed = self.timers.attention.time(|| {
                        let (q, kk, vv) = kernels::qkv_rope_par(
                            pool, &u, &lw.wq, &lw.wk, &lw.wv, positions, n, d, heads,
                            ROPE_THETA,
                        );
                        let ctx =
                            attend_rows(pool, &q, &kk, &vv, states, cache_of, li, d, heads, hd);
                        kernels::matmul_par(pool, &ctx, &lw.wo, n, d, d)
                    });
                    for r in 0..n {
                        routed[r].push(true);
                        g_attn[r].push(1.0);
                    }
                }
                LayerKind::Dtr => {
                    self.flops.add_router(li, n as u64 * (du * du + 2 * du));
                    let g = self
                        .timers
                        .router
                        .time(|| kernels::router_par(pool, &u, &lw.r_w1, &lw.r_w2, n, d, d / 2));
                    let decide = |i: usize| {
                        route == RouteOverride::Router
                            && cfg.variant != Variant::DtrSkip
                            && g[i * 2] > g[i * 2 + 1]
                    };
                    let att_idx: Vec<usize> = (0..n).filter(|&i| decide(i)).collect();
                    let byp_idx: Vec<usize> = (0..n).filter(|&i| !decide(i)).collect();
                    if !att_idx.is_empty() {
                        let rows_cache: Vec<usize> =
                            att_idx.iter().map(|&i| cache_of[i]).collect();
                        self.flops.add_qkvo(li, att_idx.len() as u64 * 8 * du * du);
                        self.flops.add_attn_mix(
                            li,
                            4 * du * attend_context_rows(states, &rows_cache, li, d),
                        );
                        self.timers.attention.time(|| {
                            let u_r = kernels::gather_rows(&u, &att_idx, d);
                            let pos_r: Vec<f32> =
                                att_idx.iter().map(|&i| positions[i]).collect();
                            let (q, kk, vv) = kernels::qkv_rope_par(
                                pool, &u_r, &lw.wq, &lw.wk, &lw.wv, &pos_r, att_idx.len(), d,
                                heads, ROPE_THETA,
                            );
                            let ctx = attend_rows(
                                pool, &q, &kk, &vv, states, &rows_cache, li, d, heads, hd,
                            );
                            let attn =
                                kernels::matmul_par(pool, &ctx, &lw.wo, att_idx.len(), d, d);
                            let g0: Vec<f32> = att_idx.iter().map(|&i| g[i * 2]).collect();
                            kernels::scatter_rows_scaled(&mut mixed, &attn, &att_idx, &g0, d);
                        });
                    }
                    if !byp_idx.is_empty() {
                        self.flops.add_bypass(li, byp_idx.len() as u64 * 4 * du * du);
                        self.timers.bypass.time(|| {
                            let u_b = kernels::gather_rows(&u, &byp_idx, d);
                            let byp =
                                kernels::bypass_par(pool, &u_b, &lw.wv, &lw.wo, byp_idx.len(), d);
                            let g1: Vec<f32> = byp_idx.iter().map(|&i| g[i * 2 + 1]).collect();
                            kernels::scatter_rows_scaled(&mut mixed, &byp, &byp_idx, &g1, d);
                        });
                    }
                    for i in 0..n {
                        routed[i].push(decide(i));
                        g_attn[i].push(g[i * 2]);
                    }
                }
                _ => bail!("unsupported layer kind in CPU backend"),
            }
            for (xv, mv) in x.iter_mut().zip(&mixed) {
                *xv += mv;
            }
            let h2 = self
                .timers
                .norm
                .time(|| kernels::rmsnorm_par(pool, &x, &lw.norm2, RMSNORM_EPS));
            self.flops.add_mlp(li, n as u64 * 6 * du * ffu);
            let mlp = self.timers.mlp.time(|| {
                kernels::swiglu_mlp_par(pool, &h2, &lw.w_gate, &lw.w_up, &lw.w_down, n, d, ff)
            });
            for (xv, mv) in x.iter_mut().zip(&mlp) {
                *xv += mv;
            }
        }

        let logit_rows = match logits {
            LogitsRows::None => 0,
            LogitsRows::Last => 1,
            LogitsRows::All => n,
        };
        self.flops
            .add_unembed(logit_rows as u64 * 2 * du * vocab as u64);
        let logits = self.timers.unembed.time(|| match logits {
            LogitsRows::None => Vec::new(),
            LogitsRows::Last => {
                let xn = kernels::rmsnorm_par(
                    pool,
                    &x[(n - 1) * d..n * d],
                    &self.weights.out_norm,
                    RMSNORM_EPS,
                );
                kernels::matmul_par(pool, &xn, &self.weights.unembed, 1, d, vocab)
            }
            LogitsRows::All => {
                let xn = kernels::rmsnorm_par(pool, &x, &self.weights.out_norm, RMSNORM_EPS);
                kernels::matmul_par(pool, &xn, &self.weights.unembed, n, d, vocab)
            }
        });
        for &c in cache_of {
            states[c].position += 1;
        }
        Ok(RowsOutput {
            logits,
            routed,
            g_attn,
        })
    }

    /// Single-sequence forward: `tokens [n]` → (logits `[n*V]`,
    /// route `[L*n]`, g_attn `[L*n]`).
    fn forward_seq(&self, tokens: &[i32]) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let cfg = &self.cfg;
        let (d, ff, vocab) = (cfg.d_model, cfg.d_ff, cfg.vocab_size);
        let (heads, hd) = (cfg.n_heads, cfg.head_dim());
        let n = tokens.len();
        let n_layers = cfg.n_layers;
        let positions: Vec<f32> = (0..n).map(|i| i as f32).collect();

        let mut x = Vec::with_capacity(n * d);
        for &t in tokens {
            ensure!(
                t >= 0 && (t as usize) < vocab,
                "token id {t} out of range for vocab {vocab}"
            );
            let t = t as usize;
            x.extend_from_slice(&self.weights.tok_embed[t * d..(t + 1) * d]);
        }

        let pool = &self.pool;
        let (du, ffu) = (d as u64, ff as u64);
        let dense_eq = dense_equiv_flops(&positions, d, ff);
        let mut route = vec![0.0f32; n_layers * n];
        let mut g_attn = vec![0.0f32; n_layers * n];
        for (li, lw) in self.weights.layers.iter().enumerate() {
            self.flops.add_dense_equiv(li, dense_eq);
            let u = self
                .timers
                .norm
                .time(|| kernels::rmsnorm_par(pool, &x, &lw.norm1, RMSNORM_EPS));
            let (mixed, delta, g0): (Vec<f32>, Vec<f32>, Vec<f32>) = match lw.kind {
                LayerKind::Dense => {
                    self.flops.add_qkvo(li, n as u64 * 8 * du * du);
                    // Causal context: row p attends over p+1 keys.
                    self.flops
                        .add_attn_mix(li, 4 * du * (n as u64 * (n as u64 + 1) / 2));
                    let attn = self.timers.attention.time(|| {
                        let (q, kk, vv) = kernels::qkv_rope_par(
                            pool, &u, &lw.wq, &lw.wk, &lw.wv, &positions, n, d, heads,
                            ROPE_THETA,
                        );
                        let ctx = kernels::dense_attention_par(pool, &q, &kk, &vv, n, heads, hd);
                        kernels::matmul_par(pool, &ctx, &lw.wo, n, d, d)
                    });
                    (attn, vec![1.0; n], vec![1.0; n])
                }
                LayerKind::Dtr => {
                    self.flops.add_router(li, n as u64 * (du * du + 2 * du));
                    let g = self
                        .timers
                        .router
                        .time(|| kernels::router_par(pool, &u, &lw.r_w1, &lw.r_w2, n, d, d / 2));
                    let delta = self.decide(&g, n);
                    // Routed rows pay QKVO + attention over the routed
                    // prefix (only routed tokens hold KV); the rest the
                    // bypass. Mirrors what dtr_token_mix_par executes.
                    let (mut att, mut ctx_total) = (0u64, 0u64);
                    for &dv in &delta {
                        if dv > 0.5 {
                            att += 1;
                            ctx_total += att;
                        }
                    }
                    self.flops.add_qkvo(li, att * 8 * du * du);
                    self.flops.add_attn_mix(li, 4 * du * ctx_total);
                    self.flops.add_bypass(li, (n as u64 - att) * 4 * du * du);
                    // shared with the golden-tested oracle mirror
                    // (kernels::dtr_token_update) — one implementation
                    let mixed = self.timers.attention.time(|| {
                        kernels::dtr_token_mix_par(
                            pool, &u, &g, &delta, &lw.wq, &lw.wk, &lw.wv, &lw.wo, &positions,
                            n, d, heads, ROPE_THETA, true,
                        )
                    });
                    let g0 = (0..n).map(|i| g[i * 2]).collect();
                    (mixed, delta, g0)
                }
                _ => bail!("unsupported layer kind in CPU backend"),
            };
            for (xv, mv) in x.iter_mut().zip(&mixed) {
                *xv += mv;
            }
            let h2 = self
                .timers
                .norm
                .time(|| kernels::rmsnorm_par(pool, &x, &lw.norm2, RMSNORM_EPS));
            self.flops.add_mlp(li, n as u64 * 6 * du * ffu);
            let mlp = self.timers.mlp.time(|| {
                kernels::swiglu_mlp_par(pool, &h2, &lw.w_gate, &lw.w_up, &lw.w_down, n, d, ff)
            });
            for (xv, mv) in x.iter_mut().zip(&mlp) {
                *xv += mv;
            }
            route[li * n..(li + 1) * n].copy_from_slice(&delta);
            g_attn[li * n..(li + 1) * n].copy_from_slice(&g0);
        }

        self.flops.add_unembed(n as u64 * 2 * du * vocab as u64);
        let logits = self.timers.unembed.time(|| {
            let xn = kernels::rmsnorm_par(pool, &x, &self.weights.out_norm, RMSNORM_EPS);
            kernels::matmul_par(pool, &xn, &self.weights.unembed, n, d, vocab)
        });
        Ok((logits, route, g_attn))
    }
}

impl Backend for CpuBackend {
    fn name(&self) -> &'static str {
        "cpu"
    }

    fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn kernel_timings(&self) -> Option<Json> {
        Some(self.timers.snapshot_with_ctx(self.pool.kernel_ctx()))
    }

    fn flop_counters(&self) -> Option<&FlopCounters> {
        Some(&self.flops)
    }

    fn forward(&self, tokens: &Tensor) -> Result<ForwardOutput> {
        ensure!(
            tokens.shape.len() == 2,
            "forward expects [B, S] tokens, got shape {:?}",
            tokens.shape
        );
        let (b, s) = (tokens.shape[0], tokens.shape[1]);
        let n_layers = self.cfg.n_layers;
        let vocab = self.cfg.vocab_size;
        let ids = tokens.as_i32();

        let mut logits = Vec::with_capacity(b * s * vocab);
        let mut route = Vec::with_capacity(b * n_layers * s);
        let mut g_attn = Vec::with_capacity(b * n_layers * s);
        for bi in 0..b {
            let (lg, rt, ga) = self.forward_seq(&ids[bi * s..(bi + 1) * s])?;
            logits.extend_from_slice(&lg);
            route.extend_from_slice(&rt);
            g_attn.extend_from_slice(&ga);
        }
        let mut attn_frac = vec![0.0f64; n_layers];
        for bi in 0..b {
            for l in 0..n_layers {
                let row = &route[(bi * n_layers + l) * s..(bi * n_layers + l + 1) * s];
                attn_frac[l] += row.iter().map(|&r| r as f64).sum::<f64>() / (b * s) as f64;
            }
        }
        Ok(ForwardOutput {
            logits: Tensor::f32(vec![b, s, vocab], logits),
            route: Tensor::f32(vec![b, n_layers, s], route),
            g_attn: Tensor::f32(vec![b, n_layers, s], g_attn),
            attn_frac,
        })
    }

    fn begin_decode(&self) -> DecodeState {
        DecodeState::new(self.cfg.n_layers)
    }

    /// Single-row decode via the shared row-step core (a single row is
    /// exactly the sequential decode semantics: same kernels, same cache
    /// appends, same position bump). [`RouteOverride::ForceBypass`] is
    /// the speculative draft pass — every DTR layer takes the linear
    /// bypass (router still evaluated, its soft score still scales the
    /// bypass update); dense layers still attend and cache.
    fn decode_step_routed(
        &self,
        state: &mut DecodeState,
        token: i32,
        route: RouteOverride,
    ) -> Result<StepOutput> {
        let positions = [state.position as f32];
        let mut slab = [&mut *state];
        let RowsOutput {
            logits,
            mut routed,
            mut g_attn,
        } = self.step_rows(&[token], &positions, &mut slab, &[0], LogitsRows::All, route)?;
        Ok(StepOutput {
            logits: Tensor::f32(vec![self.cfg.vocab_size], logits),
            routed: routed.pop().unwrap(),
            g_attn: g_attn.pop().unwrap(),
        })
    }

    /// Vectorized multi-sequence decode: one token per sequence, sharing
    /// the norm/router/MLP/unembed matmuls across the batch via
    /// [`CpuBackend::step_rows`] (each row mapped to its own sequence's
    /// cache). Attention stays per-sequence. Bit-identical to
    /// per-sequence [`Backend::decode_step`].
    fn decode_batch(
        &self,
        states: &mut [&mut DecodeState],
        tokens: &[i32],
    ) -> Result<Vec<StepOutput>> {
        ensure!(
            states.len() == tokens.len(),
            "decode_batch: {} states vs {} tokens",
            states.len(),
            tokens.len()
        );
        let b = states.len();
        if b == 0 {
            return Ok(Vec::new());
        }
        let positions: Vec<f32> = states.iter().map(|s| s.position as f32).collect();
        let cache_of: Vec<usize> = (0..b).collect();
        let RowsOutput {
            logits,
            routed,
            g_attn,
        } = self.step_rows(
            tokens,
            &positions,
            states,
            &cache_of,
            LogitsRows::All,
            RouteOverride::Router,
        )?;
        let vocab = self.cfg.vocab_size;
        let mut outs = Vec::with_capacity(b);
        for (i, (r, ga)) in routed.into_iter().zip(g_attn).enumerate() {
            outs.push(StepOutput {
                logits: Tensor::f32(vec![vocab], logits[i * vocab..(i + 1) * vocab].to_vec()),
                routed: r,
                g_attn: ga,
            });
        }
        Ok(outs)
    }

    /// Batched single-sequence multi-row decode — the speculative
    /// verification pass. All rows run through one
    /// [`CpuBackend::step_rows`] call mapped to the one sequence's
    /// cache (row order is causal order) with every row paying the
    /// unembed, so a k-token draft is verified under the full router in
    /// a single batched step. Bit-identical to a sequential
    /// [`Backend::decode_step`] loop.
    fn decode_rows(&self, state: &mut DecodeState, tokens: &[i32]) -> Result<Vec<StepOutput>> {
        ensure!(!tokens.is_empty(), "decode_rows needs at least one token");
        let vocab = self.cfg.vocab_size;
        for &t in tokens {
            ensure!(
                t >= 0 && (t as usize) < vocab,
                "token id {t} out of range for vocab {vocab}"
            );
        }
        ensure!(
            !matches!(self.router_mode, RouterMode::ExpertChoice { .. }),
            "expert-choice routing needs the full sequence; decode supports token-choice only"
        );
        let n = tokens.len();
        let positions: Vec<f32> = (0..n).map(|i| (state.position + i) as f32).collect();
        let cache_of = vec![0usize; n];
        let mut slab = [&mut *state];
        let RowsOutput {
            logits,
            routed,
            g_attn,
        } = self.step_rows(
            tokens,
            &positions,
            &mut slab,
            &cache_of,
            LogitsRows::All,
            RouteOverride::Router,
        )?;
        let mut outs = Vec::with_capacity(n);
        for (i, (r, ga)) in routed.into_iter().zip(g_attn).enumerate() {
            outs.push(StepOutput {
                logits: Tensor::f32(vec![vocab], logits[i * vocab..(i + 1) * vocab].to_vec()),
                routed: r,
                g_attn: ga,
            });
        }
        Ok(outs)
    }

    /// Streaming chunked prefill over [`CpuBackend::step_rows`] with
    /// every row mapped to the one sequence's cache (within-chunk
    /// causality comes from row order); intermediate chunks skip the
    /// unembed a sequential loop pays, so prompt ingestion is markedly
    /// cheaper. Also serves [`Backend::prefill_chunked`] through the
    /// trait's default adapter — one chunk loop, not two.
    fn prefill_rows(
        &self,
        state: &mut DecodeState,
        tokens: &[i32],
        chunk: usize,
    ) -> Result<PrefillRows> {
        ensure!(!tokens.is_empty(), "prefill needs at least one token");
        let vocab = self.cfg.vocab_size;
        for &t in tokens {
            ensure!(
                t >= 0 && (t as usize) < vocab,
                "token id {t} out of range for vocab {vocab}"
            );
        }
        ensure!(
            !matches!(self.router_mode, RouterMode::ExpertChoice { .. }),
            "expert-choice routing needs the full sequence; prefill supports token-choice only"
        );
        let chunk = chunk.max(1);
        let n_chunks = tokens.len().div_ceil(chunk);
        let mut routed = Vec::with_capacity(tokens.len());
        let mut g_attn = Vec::with_capacity(tokens.len());
        let mut logits = Vec::new();
        for (ci, ck) in tokens.chunks(chunk).enumerate() {
            let positions: Vec<f32> =
                (0..ck.len()).map(|i| (state.position + i) as f32).collect();
            let cache_of = vec![0usize; ck.len()];
            let mut slab = [&mut *state];
            let mode = if ci + 1 == n_chunks {
                LogitsRows::Last
            } else {
                LogitsRows::None
            };
            let out =
                self.step_rows(ck, &positions, &mut slab, &cache_of, mode, RouteOverride::Router)?;
            routed.extend(out.routed);
            g_attn.extend(out.g_attn);
            logits = out.logits;
        }
        Ok(PrefillRows {
            last: StepOutput {
                logits: Tensor::f32(vec![vocab], logits),
                routed: routed.last().unwrap().clone(),
                g_attn: g_attn.last().unwrap().clone(),
            },
            routed,
            g_attn,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xs_cfg(variant: Variant) -> ModelConfig {
        ModelConfig::preset("xs", variant)
    }

    #[test]
    fn rejects_unsupported_variants() {
        assert!(CpuBackend::init(&xs_cfg(Variant::Mod), 0).is_err());
        assert!(CpuBackend::init(&xs_cfg(Variant::Dllm), 0).is_err());
        assert!(CpuBackend::init(&xs_cfg(Variant::DtrBilayer), 0).is_ok());
    }

    #[test]
    fn dtr_skip_routes_nothing_but_still_updates() {
        let be = CpuBackend::init(&xs_cfg(Variant::DtrSkip), 1).unwrap();
        let tokens = Tensor::i32(vec![1, 8], (0..8).collect());
        let out = be.forward(&tokens).unwrap();
        let layout = be.config().layout_string();
        for (l, kind) in layout.chars().enumerate() {
            let frac = out.attn_frac[l];
            if kind == 'T' {
                assert_eq!(frac, 1.0);
            } else {
                assert_eq!(frac, 0.0, "dtr_skip layer {l} must bypass all tokens");
            }
        }
        assert!(out.logits.as_f32().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn checkpoint_roundtrip_preserves_forward() {
        let be = CpuBackend::init(&xs_cfg(Variant::DtrBilayer), 7).unwrap();
        let ck = be.to_checkpoint();
        let re = CpuBackend::from_checkpoint(be.config(), &ck).unwrap();
        let tokens = Tensor::i32(vec![1, 12], (0..12).map(|i| i * 5 % 256).collect());
        let a = be.forward(&tokens).unwrap();
        let b = re.forward(&tokens).unwrap();
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.route, b.route);
    }

    #[test]
    fn checkpoint_bytes_roundtrip() {
        let be = CpuBackend::init(&xs_cfg(Variant::DtrBilayer), 3).unwrap();
        let ck = be.to_checkpoint();
        let re = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        let be2 = CpuBackend::from_checkpoint(be.config(), &re).unwrap();
        let tokens = Tensor::i32(vec![1, 6], vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(
            be.forward(&tokens).unwrap().logits,
            be2.forward(&tokens).unwrap().logits
        );
    }
}
