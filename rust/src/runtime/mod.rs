//! Runtime: load AOT artifacts (HLO text) and execute them via PJRT.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin): artifacts produced by
//! `python/compile/aot.py` are compiled once per process and cached; the
//! coordinator calls them as plain functions over host tensors.
//!
//! Interchange is HLO *text* — the image's xla_extension 0.5.1 rejects
//! jax≥0.5 serialized protos (64-bit instruction ids); the text parser
//! reassigns ids (see /opt/xla-example/README.md).

pub mod checkpoint;
pub mod engine;
pub mod manifest;
pub mod tensor;

pub use checkpoint::Checkpoint;
pub use engine::{Engine, Executable};
pub use manifest::{ArtifactSpec, IoSpec, Manifest};
pub use tensor::Tensor;
