//! Runtime: pluggable execution backends over host tensors.
//!
//! * [`backend`] — the [`Backend`] trait: batched forward + incremental
//!   decode with a routing-aware KV state, over host [`Tensor`]s.
//! * [`kv`] — page-view KV storage ([`KvCache`]): the only surface
//!   attention reads cached K/V through; resident slab or bounded/paged
//!   with LRU spill-to-disk eviction (DESIGN.md §KV paging).
//! * [`cpu`] — the native Rust CPU backend (always available): evaluates
//!   the DTRNet block end-to-end with kernels mirrored from
//!   `python/compile/kernels/ref.py`. This is the offline test substrate.
//! * [`engine`] (`pjrt` feature) — the XLA/PJRT path: AOT artifacts (HLO
//!   text produced by `python/compile/aot.py`) compiled once per process
//!   and called as plain functions. Interchange is HLO *text* — the
//!   image's xla_extension 0.5.1 rejects jax≥0.5 serialized protos
//!   (64-bit instruction ids); the text parser reassigns ids.
//! * [`manifest`] — the artifact contract with `aot.py` (feature-free:
//!   shapes/layouts are plain host data).
//! * [`quant`] — int8 weight quantization: [`QuantMatrix`] storage, the
//!   [`QuantizedCpuBackend`] (full [`Backend`] surface, dequant-free
//!   kernels, ~3.7× weight-memory compression), and the f32-vs-int8
//!   routing/perplexity accuracy gates (DESIGN.md §Quantization).
//! * [`checkpoint`] — DTCK parameter persistence, shared by both backends.
//! * [`train`] — the [`TrainBackend`] trait (one optimizer step:
//!   forward + backward + AdamW) and the native [`CpuTrainer`], with
//!   hand-derived backward kernels in [`cpu::grads`]. The coordinator's
//!   training loop drives this trait; the PJRT `train_step` artifact
//!   path is retrofitted behind it in `coordinator::trainer`.

pub mod backend;
pub mod checkpoint;
pub mod cpu;
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod kv;
pub mod manifest;
pub mod quant;
pub mod tensor;
pub mod train;

pub use backend::{
    Backend, DecodeState, ForwardOutput, GenerateOutput, PrefillRows, RouteOverride, StateMark,
    StepOutput, WeightBytes,
};
pub use kv::{KvCache, KvPageRef};
pub use checkpoint::Checkpoint;
pub use cpu::{CpuBackend, RouterMode};
pub use quant::{QuantMatrix, QuantizedCpuBackend};
#[cfg(feature = "pjrt")]
pub use engine::{Engine, Executable};
pub use manifest::{ArtifactSpec, IoSpec, Manifest};
pub use tensor::Tensor;
pub use train::{CpuTrainer, TrainBackend, TrainMetrics};
