//! Manifest parsing: the contract between `aot.py` and the runtime.
//!
//! `artifacts/manifest.json` records, per artifact: the HLO file, kind,
//! model config, the flat parameter layout (path/shape/dtype in execution
//! order) and full input/output shape lists. The runtime trusts these
//! shapes; mismatches fail loudly at literal-build time.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::config::ModelConfig;
use crate::util::json::Json;

/// Shape+dtype of one input/output.
#[derive(Debug, Clone)]
pub struct IoSpec {
    /// Tensor shape (row-major).
    pub shape: Vec<usize>,
    /// Element type name ("f32"/"i32").
    pub dtype: String,
}

impl IoSpec {
    fn from_json(j: &Json) -> IoSpec {
        IoSpec {
            shape: j
                .get("shape")
                .and_then(|s| s.as_arr())
                .map(|a| a.iter().map(|v| v.as_usize().unwrap()).collect())
                .unwrap_or_default(),
            dtype: j
                .get("dtype")
                .and_then(|d| d.as_str())
                .unwrap_or("float32")
                .to_string(),
        }
    }

    /// Element count (shape product).
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    /// True for zero-sized tensors.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One named parameter in the flat layout.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    /// Path of the packed parameter file, relative to the artifact dir.
    pub path: String,
    /// Tensor shape (row-major).
    pub shape: Vec<usize>,
}

/// One artifact entry.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Artifact name (the manifest key).
    pub name: String,
    /// HLO text file, relative to the artifact dir.
    pub file: String,
    /// Artifact kind: fwd / decode / train_step / init.
    pub kind: String,
    /// Model configuration the artifact was lowered for.
    pub config: ModelConfig,
    /// The raw config JSON (manifest round-trip fidelity).
    pub config_json: Json,
    /// Packed parameter files in call order.
    pub params: Vec<ParamSpec>,
    /// Input tensor specs in call order.
    pub inputs: Vec<IoSpec>,
    /// Output tensor specs in call order.
    pub outputs: Vec<IoSpec>,
    /// Batch dimension, when the artifact fixes one.
    pub batch: Option<usize>,
    /// Sequence length, when the artifact fixes one.
    pub seq: Option<usize>,
    /// KV capacity, for decode artifacts.
    pub max_kv: Option<usize>,
    /// Parameter count, when recorded.
    pub nparams: Option<usize>,
}

/// Parsed manifest: artifact index by name.
#[derive(Debug)]
pub struct Manifest {
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Artifact specs in manifest order.
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Parse `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let j = Json::parse_file(&path)
            .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
        let arts = j
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .context("manifest missing artifacts[]")?;
        let artifacts = arts
            .iter()
            .map(|a| {
                let cfg_json = a.get("config").cloned().unwrap_or(Json::obj());
                ArtifactSpec {
                    name: a.get("name").and_then(|v| v.as_str()).unwrap().to_string(),
                    file: a.get("file").and_then(|v| v.as_str()).unwrap().to_string(),
                    kind: a.get("kind").and_then(|v| v.as_str()).unwrap().to_string(),
                    config: ModelConfig::from_manifest(&cfg_json),
                    config_json: cfg_json,
                    params: a
                        .get("params")
                        .and_then(|p| p.as_arr())
                        .map(|ps| {
                            ps.iter()
                                .map(|p| ParamSpec {
                                    path: p
                                        .get("path")
                                        .and_then(|v| v.as_str())
                                        .unwrap()
                                        .to_string(),
                                    shape: p
                                        .get("shape")
                                        .and_then(|s| s.as_arr())
                                        .map(|a| {
                                            a.iter().map(|v| v.as_usize().unwrap()).collect()
                                        })
                                        .unwrap_or_default(),
                                })
                                .collect()
                        })
                        .unwrap_or_default(),
                    inputs: a
                        .get("inputs")
                        .and_then(|v| v.as_arr())
                        .map(|a| a.iter().map(IoSpec::from_json).collect())
                        .unwrap_or_default(),
                    outputs: a
                        .get("outputs")
                        .and_then(|v| v.as_arr())
                        .map(|a| a.iter().map(IoSpec::from_json).collect())
                        .unwrap_or_default(),
                    batch: a.get("batch").and_then(|v| v.as_usize()),
                    seq: a.get("seq").and_then(|v| v.as_usize()),
                    max_kv: a.get("max_kv").and_then(|v| v.as_usize()),
                    nparams: a.get("nparams").and_then(|v| v.as_usize()),
                }
            })
            .collect();
        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
        })
    }

    /// Look up an artifact by exact name.
    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| {
                anyhow!(
                    "artifact {name:?} not in manifest (have: {})",
                    self.artifacts
                        .iter()
                        .map(|a| a.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }

    /// All artifacts of a given kind (e.g. every `fwd`).
    pub fn by_kind(&self, kind: &str) -> Vec<&ArtifactSpec> {
        self.artifacts.iter().filter(|a| a.kind == kind).collect()
    }

    /// Absolute path of an artifact's HLO text file.
    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}
