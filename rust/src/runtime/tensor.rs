//! Host tensors: the coordinator's view of model inputs/outputs.
//!
//! A `Tensor` is a shape + flat row-major data buffer (f32 or i32 — the
//! only element types crossing the AOT boundary in this system). It
//! converts to/from `xla::Literal` at the runtime edge.

use anyhow::{bail, Result};

/// View a 4-byte-element slice as raw bytes (safe: both f32 and i32 are
/// plain-old-data with alignment ≥ u8).
fn bytemuck_cast<T>(v: &[T]) -> &[u8] {
    unsafe {
        std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v))
    }
}

/// Element storage.
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Dense row-major host tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor {
            shape,
            data: Data::F32(data),
        }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor {
            shape,
            data: Data::I32(data),
        }
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::f32(vec![], vec![v])
    }

    pub fn scalar_i32(v: i32) -> Tensor {
        Tensor::i32(vec![], vec![v])
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::f32(shape, vec![0.0; n])
    }

    pub fn zeros_i32(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::i32(shape, vec![0; n])
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            Data::F32(v) => v,
            Data::I32(_) => panic!("tensor is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            Data::I32(v) => v,
            Data::F32(_) => panic!("tensor is f32, expected i32"),
        }
    }

    pub fn scalar(&self) -> f32 {
        match &self.data {
            Data::F32(v) => v[0],
            Data::I32(v) => v[0] as f32,
        }
    }

    /// Row-major strides for this shape.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1usize; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    /// Index with a multi-dim coordinate (debug/eval helper, not hot path).
    pub fn at(&self, idx: &[usize]) -> f32 {
        let strides = self.strides();
        let flat: usize = idx.iter().zip(&strides).map(|(i, s)| i * s).sum();
        match &self.data {
            Data::F32(v) => v[flat],
            Data::I32(v) => v[flat] as f32,
        }
    }

    // ---- Literal conversion -------------------------------------------------

    pub fn to_literal(&self) -> Result<xla::Literal> {
        // Single-copy path (§Perf L3): build the shaped literal directly
        // from raw bytes. The vec1 + reshape route copies twice (once into
        // the rank-1 literal, once in reshape) — measured 2.4× slower on
        // the 12 MB decode-cache pack (see EXPERIMENTS.md §Perf).
        let (ty, bytes): (xla::ElementType, &[u8]) = match &self.data {
            Data::F32(v) => (xla::ElementType::F32, bytemuck_cast(v)),
            Data::I32(v) => (xla::ElementType::S32, bytemuck_cast(v)),
        };
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            ty,
            &self.shape,
            bytes,
        )?)
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::f32(dims, lit.to_vec::<f32>()?)),
            xla::ElementType::S32 => Ok(Tensor::i32(dims, lit.to_vec::<i32>()?)),
            other => bail!("unsupported element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_and_at() {
        let t = Tensor::f32(vec![2, 3], vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(t.strides(), vec![3, 1]);
        assert_eq!(t.at(&[1, 2]), 5.0);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::f32(vec![2, 2], vec![1.0]);
    }
}
