//! Host tensors: the backends' shared view of model inputs/outputs.
//!
//! A `Tensor` is a shape + flat row-major data buffer (f32 or i32 — the
//! only element types crossing execution boundaries in this system). The
//! native CPU backend consumes it directly; with the `pjrt` feature it
//! also converts to/from `xla::Literal` at the runtime edge.

#[cfg(feature = "pjrt")]
use anyhow::{bail, Result};

/// Element types whose slices may be viewed as raw bytes.
///
/// Sealed to exactly `f32` and `i32`: both are plain-old-data — no
/// padding, no invalid bit patterns, 4-byte size, alignment ≥ 1 — which
/// is what makes the byte view in [`bytes_of`] sound. Restricting the
/// generic at the type level (instead of the old `bytemuck_cast<T>` over
/// *any* `T`) means a padded or non-POD element type is a compile error,
/// not latent UB.
pub trait Pod: sealed::Sealed + Copy + 'static {}

impl Pod for f32 {}
impl Pod for i32 {}

mod sealed {
    /// Marker restricting [`Pod`] to in-repo types.
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
}

/// View a slice of [`Pod`] elements as native-endian raw bytes.
pub fn bytes_of<T: Pod>(v: &[T]) -> &[u8] {
    // Both admitted types are 4-byte POD; keep the guard as a defensive
    // invariant should the sealed set ever grow.
    debug_assert_eq!(std::mem::size_of::<T>(), 4);
    debug_assert_eq!(std::mem::size_of_val(v), v.len() * 4);
    // SAFETY: `T: Pod` is sealed to f32/i32 — plain-old-data with no
    // padding and no invalid byte patterns; u8 has alignment 1, and the
    // byte length equals the slice's size in bytes.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
}

/// Element storage.
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    /// 32-bit float payload.
    F32(Vec<f32>),
    /// 32-bit integer payload.
    I32(Vec<i32>),
}

/// Dense row-major host tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    /// Row-major shape.
    pub shape: Vec<usize>,
    /// Typed flat payload.
    pub data: Data,
}

impl Tensor {
    /// An f32 tensor (panics if data doesn't match the shape product).
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor {
            shape,
            data: Data::F32(data),
        }
    }

    /// An i32 tensor (panics if data doesn't match the shape product).
    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor {
            shape,
            data: Data::I32(data),
        }
    }

    /// A rank-0 f32 tensor.
    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::f32(vec![], vec![v])
    }

    /// A rank-0 i32 tensor.
    pub fn scalar_i32(v: i32) -> Tensor {
        Tensor::i32(vec![], vec![v])
    }

    /// An all-zero f32 tensor of `shape`.
    pub fn zeros_f32(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::f32(shape, vec![0.0; n])
    }

    /// An all-zero i32 tensor of `shape`.
    pub fn zeros_i32(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::i32(shape, vec![0; n])
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    /// True for zero-sized tensors.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The f32 payload (panics on i32 tensors).
    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            Data::F32(v) => v,
            Data::I32(_) => panic!("tensor is i32, expected f32"),
        }
    }

    /// The i32 payload (panics on f32 tensors).
    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            Data::I32(v) => v,
            Data::F32(_) => panic!("tensor is f32, expected i32"),
        }
    }

    /// The single element of a rank-0/len-1 tensor, as f64-free f32.
    pub fn scalar(&self) -> f32 {
        match &self.data {
            Data::F32(v) => v[0],
            Data::I32(v) => v[0] as f32,
        }
    }

    /// Native-endian raw-byte view of the element buffer.
    pub fn raw_bytes(&self) -> &[u8] {
        match &self.data {
            Data::F32(v) => bytes_of(v.as_slice()),
            Data::I32(v) => bytes_of(v.as_slice()),
        }
    }

    /// Row-major strides for this shape.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1usize; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    /// Index with a multi-dim coordinate (debug/eval helper, not hot path).
    pub fn at(&self, idx: &[usize]) -> f32 {
        let strides = self.strides();
        let flat: usize = idx.iter().zip(&strides).map(|(i, s)| i * s).sum();
        match &self.data {
            Data::F32(v) => v[flat],
            Data::I32(v) => v[flat] as f32,
        }
    }
}

// ---- Literal conversion (PJRT boundary) -----------------------------------

#[cfg(feature = "pjrt")]
impl Tensor {
    /// Convert to a PJRT literal (pjrt builds).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        // Single-copy path (§Perf L3): build the shaped literal directly
        // from raw bytes. The vec1 + reshape route copies twice (once into
        // the rank-1 literal, once in reshape) — measured 2.4× slower on
        // the 12 MB decode-cache pack (see EXPERIMENTS.md §Perf).
        let ty = match &self.data {
            Data::F32(_) => xla::ElementType::F32,
            Data::I32(_) => xla::ElementType::S32,
        };
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            ty,
            &self.shape,
            self.raw_bytes(),
        )?)
    }

    /// Convert from a PJRT literal (pjrt builds).
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::f32(dims, lit.to_vec::<f32>()?)),
            xla::ElementType::S32 => Ok(Tensor::i32(dims, lit.to_vec::<i32>()?)),
            other => bail!("unsupported element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_and_at() {
        let t = Tensor::f32(vec![2, 3], vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(t.strides(), vec![3, 1]);
        assert_eq!(t.at(&[1, 2]), 5.0);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::f32(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn bytes_roundtrip_f32() {
        let v = vec![1.5f32, -2.25, 0.0, f32::MIN_POSITIVE, 1e30];
        let bytes = bytes_of(v.as_slice());
        assert_eq!(bytes.len(), v.len() * 4);
        let back: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_ne_bytes(c.try_into().unwrap()))
            .collect();
        assert_eq!(back, v);
    }

    #[test]
    fn bytes_roundtrip_i32() {
        let v = vec![0i32, -1, i32::MAX, i32::MIN, 123456789];
        let bytes = bytes_of(v.as_slice());
        let back: Vec<i32> = bytes
            .chunks_exact(4)
            .map(|c| i32::from_ne_bytes(c.try_into().unwrap()))
            .collect();
        assert_eq!(back, v);
    }

    #[test]
    fn tensor_raw_bytes_matches_dtype_width() {
        let t = Tensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.raw_bytes().len(), 16);
        let t = Tensor::i32(vec![3], vec![7, 8, 9]);
        assert_eq!(t.raw_bytes().len(), 12);
    }
}
