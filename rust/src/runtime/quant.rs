//! Int8 weight quantization — the memory-bandwidth half of the serving
//! story (DESIGN.md §Quantization).
//!
//! DTRNet serving skips quadratic attention for ~90% of tokens, which
//! leaves CPU decode increasingly *weight-bandwidth*-bound — exactly the
//! regime where 4×-smaller weights pay off. This module provides:
//!
//! * [`QuantMatrix`] — per-output-row symmetric int8 storage of one
//!   weight matrix (`i8` data + one `f32` scale per output channel,
//!   transposed so the matmul inner loop is two contiguous streams);
//! * [`QuantizedCpuBackend`] — the full [`Backend`] surface (forward,
//!   chunked prefill, batched decode, kernel timings) evaluated
//!   dequant-free over quantized projections via
//!   [`kernels::matmul_q8_par`];
//! * [`check_routing_equivalence`] — the f32-vs-int8 routing gate the
//!   perf harness and tests enforce.
//!
//! # What is quantized
//!
//! Every large matrix: `tok_embed`, `unembed`, and the seven per-layer
//! projections (`wq`/`wk`/`wv`/`wo`/`w_gate`/`w_up`/`w_down`). Norm
//! gains and the DTR router weights (`r_w1`/`r_w2`) stay f32: together
//! they are ~3% of parameters, and the router is the component whose
//! *decisions* the accuracy gates compare against f32 — keeping its own
//! weights exact confines quantization noise to the router's *input*
//! stream. Net weight-memory compression is ~3.7× (≥3.5× gated).
//!
//! # Determinism
//!
//! The quantized kernels follow the PR 3 discipline: every output
//! element is one ascending-k f32 accumulation computed whole inside a
//! single disjoint chunk, so forward/prefill/decode are **bit-identical
//! across `--threads`** (property-tested in `rust/tests/quant.rs`).
//!
//! # Accuracy gates
//!
//! Quantization perturbs the residual stream by ~0.1%, so a token whose
//! f32 router margin `|g_attn − g_bypass|` sits *below* that noise floor
//! can legitimately flip paths — exact decision equality is not
//! information-theoretically guaranteeable under any weight perturbation.
//! The gate therefore demands exact equality wherever the f32 router is
//! decisive (margin ≥ [`ROUTING_MARGIN_TOL`]) and bounds near-tie flips
//! to [`ROUTING_MAX_FLIP_FRAC`] of DTR-layer decisions (dense layers
//! cannot flip and are excluded); eval perplexity must stay
//! within 0.5% of f32 (enforced by the `quant_*` perf scenarios;
//! measured deltas are ~0.05%).

use anyhow::{bail, ensure, Result};

use crate::config::{LayerKind, ModelConfig, Variant};
use crate::metrics::KernelTimers;
use crate::util::json::Json;
use crate::util::threadpool::{self, Pool};

use crate::telemetry::FlopCounters;

use super::backend::{
    Backend, DecodeState, ForwardOutput, PrefillRows, RouteOverride, StepOutput, WeightBytes,
};
use super::checkpoint::Checkpoint;
use super::cpu::{
    attend_context_rows, attend_rows, dense_equiv_flops, init_weights, kernels, validate_weights,
    CpuBackend, ModelWeights, RouterMode, RMSNORM_EPS, ROPE_THETA,
};
use super::tensor::Tensor;

/// A weight matrix in per-output-row symmetric int8 form.
///
/// Logical shape `[k, m]` (the row-major `x @ W` layout); stored
/// transposed as `m` contiguous i8 rows of length `k`, one f32 scale per
/// output row: `W[kk, j] ≈ data[j*k + kk] * scales[j]`.
#[derive(Debug, Clone)]
pub struct QuantMatrix {
    /// Input dimension (rows of the logical f32 matrix).
    k: usize,
    /// Output dimension (columns of the logical f32 matrix).
    m: usize,
    /// `[m, k]` output-row-major int8 codes.
    data: Vec<i8>,
    /// `[m]` per-output-row scales (`amax/127`; 1.0 for all-zero rows).
    scales: Vec<f32>,
}

impl QuantMatrix {
    /// Quantize a row-major `[k, m]` f32 matrix (per-output-row scales).
    pub fn quantize(w: &[f32], k: usize, m: usize) -> QuantMatrix {
        let (data, scales) = kernels::quantize_rows(w, k, m);
        QuantMatrix { k, m, data, scales }
    }

    /// Quantize a matrix whose *storage rows* are already the output
    /// channels (`[m, k]` row-major — the `tok_embed` lookup layout).
    pub fn quantize_row_major(w: &[f32], m: usize, k: usize) -> QuantMatrix {
        debug_assert_eq!(w.len(), m * k);
        let mut scales = vec![0.0f32; m];
        let mut data = vec![0i8; m * k];
        for j in 0..m {
            let row = &w[j * k..(j + 1) * k];
            let amax = row.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            let s = if amax > 0.0 { amax / 127.0 } else { 1.0 };
            scales[j] = s;
            for (q, &v) in data[j * k..(j + 1) * k].iter_mut().zip(row) {
                *q = (v / s).round().clamp(-127.0, 127.0) as i8;
            }
        }
        QuantMatrix { k, m, data, scales }
    }

    /// Input dimension k.
    pub fn input_dim(&self) -> usize {
        self.k
    }

    /// Output dimension m.
    pub fn output_dim(&self) -> usize {
        self.m
    }

    /// Per-output-row scales.
    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    /// `a [n, k] @ W -> [n, m]` without dequantizing the weights
    /// ([`kernels::matmul_q8_par`]; bit-identical for any thread count).
    pub fn matmul_par(&self, pool: &Pool, a: &[f32], n: usize) -> Vec<f32> {
        kernels::matmul_q8_par(pool, a, &self.data, &self.scales, n, self.k, self.m)
    }

    /// Dequantize output row `j` into `out` (`out[i] = q[j,i] * scale[j]`,
    /// exact f32 products — the embedding-lookup path).
    pub fn dequant_row_into(&self, j: usize, out: &mut Vec<f32>) {
        let s = self.scales[j];
        let row = &self.data[j * self.k..(j + 1) * self.k];
        out.extend(row.iter().map(|&q| q as f32 * s));
    }

    /// Reconstruct the logical `[k, m]` row-major f32 matrix.
    pub fn dequantize(&self) -> Vec<f32> {
        let mut w = vec![0.0f32; self.k * self.m];
        for j in 0..self.m {
            let s = self.scales[j];
            for kk in 0..self.k {
                w[kk * self.m + j] = self.data[j * self.k + kk] as f32 * s;
            }
        }
        w
    }

    /// Resident bytes (i8 codes + f32 scales).
    pub fn bytes(&self) -> usize {
        self.data.len() + 4 * self.scales.len()
    }

    /// Bytes the f32 form of this matrix occupies.
    pub fn f32_bytes(&self) -> usize {
        4 * self.k * self.m
    }
}

/// One layer's weights in quantized form (norms + router stay f32).
#[derive(Debug, Clone)]
pub struct QuantLayerWeights {
    /// Block kind (checked against the config at construction).
    pub kind: LayerKind,
    /// Pre-attention RMSNorm gain `[d]` (f32).
    pub norm1: Vec<f32>,
    /// Pre-MLP RMSNorm gain `[d]` (f32).
    pub norm2: Vec<f32>,
    /// Query projection `[d, d]`.
    pub wq: QuantMatrix,
    /// Key projection `[d, d]`.
    pub wk: QuantMatrix,
    /// Value projection `[d, d]`.
    pub wv: QuantMatrix,
    /// Output projection `[d, d]`.
    pub wo: QuantMatrix,
    /// SwiGLU gate projection `[d, ff]`.
    pub w_gate: QuantMatrix,
    /// SwiGLU up projection `[d, ff]`.
    pub w_up: QuantMatrix,
    /// SwiGLU down projection `[ff, d]`.
    pub w_down: QuantMatrix,
    /// Router first layer `[d, d/2]` (f32; empty on dense layers).
    pub r_w1: Vec<f32>,
    /// Router second layer `[d/2, 2]` (f32; empty on dense layers).
    pub r_w2: Vec<f32>,
}

/// Full parameter set in quantized form.
#[derive(Debug, Clone)]
pub struct QuantModelWeights {
    /// Token embedding `[V, d]`, quantized per embedding row.
    pub tok_embed: QuantMatrix,
    /// Unembedding `[d, V]`, quantized per vocab column.
    pub unembed: QuantMatrix,
    /// Final RMSNorm gain `[d]` (f32).
    pub out_norm: Vec<f32>,
    /// Per-layer weights, in layer order.
    pub layers: Vec<QuantLayerWeights>,
}

impl QuantModelWeights {
    /// Quantize a validated f32 parameter set.
    pub fn from_f32(cfg: &ModelConfig, w: &ModelWeights) -> QuantModelWeights {
        let (d, ff, v) = (cfg.d_model, cfg.d_ff, cfg.vocab_size);
        let layers = w
            .layers
            .iter()
            .map(|lw| QuantLayerWeights {
                kind: lw.kind,
                norm1: lw.norm1.clone(),
                norm2: lw.norm2.clone(),
                wq: QuantMatrix::quantize(&lw.wq, d, d),
                wk: QuantMatrix::quantize(&lw.wk, d, d),
                wv: QuantMatrix::quantize(&lw.wv, d, d),
                wo: QuantMatrix::quantize(&lw.wo, d, d),
                w_gate: QuantMatrix::quantize(&lw.w_gate, d, ff),
                w_up: QuantMatrix::quantize(&lw.w_up, d, ff),
                w_down: QuantMatrix::quantize(&lw.w_down, ff, d),
                r_w1: lw.r_w1.clone(),
                r_w2: lw.r_w2.clone(),
            })
            .collect();
        QuantModelWeights {
            tok_embed: QuantMatrix::quantize_row_major(&w.tok_embed, v, d),
            unembed: QuantMatrix::quantize(&w.unembed, d, v),
            out_norm: w.out_norm.clone(),
            layers,
        }
    }

    /// Resident vs f32-equivalent weight footprint (the ServeReport
    /// telemetry; the f32 side counts every tensor at 4 bytes/param).
    pub fn weight_bytes(&self) -> WeightBytes {
        let mut resident = 4 * self.out_norm.len();
        let mut f32_equiv = 4 * self.out_norm.len();
        for qm in [&self.tok_embed, &self.unembed] {
            resident += qm.bytes();
            f32_equiv += qm.f32_bytes();
        }
        for lw in &self.layers {
            let f32_side = lw.norm1.len() + lw.norm2.len() + lw.r_w1.len() + lw.r_w2.len();
            resident += 4 * f32_side;
            f32_equiv += 4 * f32_side;
            for qm in [
                &lw.wq, &lw.wk, &lw.wv, &lw.wo, &lw.w_gate, &lw.w_up, &lw.w_down,
            ] {
                resident += qm.bytes();
                f32_equiv += qm.f32_bytes();
            }
        }
        WeightBytes { resident, f32_equiv }
    }
}

/// Near-tie threshold for the routing-equivalence gate: decisions whose
/// f32 margin `|g_attn − g_bypass|` is at least this must match int8
/// exactly; below it a flip is tolerated (the margin sits inside the
/// quantization noise floor — measured flips occur under ~2e-3).
pub const ROUTING_MARGIN_TOL: f32 = 0.05;

/// Maximum fraction of **DTR-layer** routing decisions allowed to flip
/// (near-tie flips included; dense layers are pinned and excluded from
/// the denominator so the budget does not dilute with the dense share of
/// the layout). Measured rates are ≤0.5%; the gate allows 5% — the
/// decisive-flip rule above carries the strictness, this bounds the
/// volume of near-tie churn.
pub const ROUTING_MAX_FLIP_FRAC: f64 = 0.05;

/// Outcome of [`compare_routing`].
#[derive(Debug, Clone, Copy)]
pub struct RoutingEquivalence {
    /// Total (token, layer) routing decisions compared (dense included).
    pub decisions: usize,
    /// Decisions on DTR layers (`g_attn < 1.0`) — the flip-budget
    /// denominator; dense layers are structurally unable to flip.
    pub dtr_decisions: usize,
    /// Decisions where the int8 path chose differently from f32.
    pub flips: usize,
    /// Flips at tokens where the f32 margin was ≥ [`ROUTING_MARGIN_TOL`]
    /// — these are never acceptable.
    pub decisive_flips: usize,
    /// Smallest f32 margin observed on a DTR decision (diagnostics).
    pub min_f32_margin: f32,
}

/// Compare hard routing decisions of an f32 and an int8 forward pass
/// over the same tokens. Both outputs must have identical shapes.
pub fn compare_routing(f32_out: &ForwardOutput, int8_out: &ForwardOutput) -> RoutingEquivalence {
    debug_assert_eq!(f32_out.route.shape, int8_out.route.shape);
    let rf = f32_out.route.as_f32();
    let rq = int8_out.route.as_f32();
    let gf = f32_out.g_attn.as_f32();
    let mut eq = RoutingEquivalence {
        decisions: rf.len(),
        dtr_decisions: 0,
        flips: 0,
        decisive_flips: 0,
        min_f32_margin: f32::INFINITY,
    };
    // zip (not indexing) so a shape mismatch that slipped past the
    // debug_assert cannot out-of-bounds in release — the comparison just
    // covers the common prefix (check_routing_equivalence rejects
    // mismatched shapes up front with a real error).
    for ((&rfi, &rqi), &gfi) in rf.iter().zip(rq).zip(gf) {
        // Two-way softmax: g_bypass = 1 - g_attn, margin = |2g - 1|.
        // Dense layers are pinned at g = 1.0 (margin 1, never flips).
        let margin = (2.0 * gfi - 1.0).abs();
        if gfi < 1.0 {
            eq.dtr_decisions += 1;
            eq.min_f32_margin = eq.min_f32_margin.min(margin);
        }
        if (rfi > 0.5) != (rqi > 0.5) {
            eq.flips += 1;
            if margin >= ROUTING_MARGIN_TOL {
                eq.decisive_flips += 1;
            }
        }
    }
    eq
}

/// The routing-equivalence gate: zero decisive flips, and total flips
/// bounded by [`ROUTING_MAX_FLIP_FRAC`]. Returns the comparison stats on
/// success so callers can record them.
pub fn check_routing_equivalence(
    f32_out: &ForwardOutput,
    int8_out: &ForwardOutput,
) -> Result<RoutingEquivalence> {
    ensure!(
        f32_out.route.shape == int8_out.route.shape,
        "routing shapes differ: {:?} vs {:?}",
        f32_out.route.shape,
        int8_out.route.shape
    );
    let eq = compare_routing(f32_out, int8_out);
    ensure!(
        eq.decisive_flips == 0,
        "int8 flipped {} decisive routing decisions (f32 margin >= {ROUTING_MARGIN_TOL}) \
         of {} — quantization noise must not override a confident router",
        eq.decisive_flips,
        eq.decisions
    );
    let frac = eq.flips as f64 / eq.dtr_decisions.max(1) as f64;
    ensure!(
        frac <= ROUTING_MAX_FLIP_FRAC,
        "int8 flipped {} of {} DTR routing decisions ({:.3}% > {:.1}% budget)",
        eq.flips,
        eq.dtr_decisions,
        frac * 100.0,
        ROUTING_MAX_FLIP_FRAC * 100.0
    );
    Ok(eq)
}

/// Which rows of a step need logits (mirror of the f32 backend's enum).
#[derive(Clone, Copy, PartialEq)]
enum LogitsRows {
    All,
    Last,
    None,
}

/// Output of [`QuantizedCpuBackend::step_rows`].
struct RowsOutput {
    logits: Vec<f32>,
    routed: Vec<Vec<bool>>,
    g_attn: Vec<Vec<f32>>,
}

/// The int8-quantized CPU execution backend.
///
/// Semantics mirror [`CpuBackend`] exactly — same block structure, same
/// routing rules, same cache contract — with every large matmul running
/// through [`QuantMatrix::matmul_par`]. Outputs are *not* bit-identical
/// to the f32 backend (weights differ by construction); they are
/// bit-identical to themselves across thread counts, and held to f32
/// behavior by the routing-equivalence and perplexity-delta gates.
pub struct QuantizedCpuBackend {
    cfg: ModelConfig,
    weights: QuantModelWeights,
    router_mode: RouterMode,
    pool: Pool,
    timers: KernelTimers,
    /// Measured per-layer FLOP accounting (int8 MACs counted at the same
    /// 2-FLOPs-per-MAC convention as f32 — the counters measure *work
    /// shape*, not instruction mix).
    flops: FlopCounters,
}

impl QuantizedCpuBackend {
    /// Quantize a validated f32 parameter set into a ready backend.
    pub fn from_weights(
        cfg: &ModelConfig,
        weights: &ModelWeights,
        mode: RouterMode,
    ) -> Result<QuantizedCpuBackend> {
        validate_weights(cfg, weights)?;
        Ok(QuantizedCpuBackend {
            cfg: cfg.clone(),
            weights: QuantModelWeights::from_f32(cfg, weights),
            router_mode: mode,
            pool: threadpool::global().clone(),
            timers: KernelTimers::default(),
            flops: FlopCounters::new(cfg.n_layers),
        })
    }

    /// Seeded random initialization, quantized — bit-for-bit the same
    /// f32 init as [`CpuBackend::init`] before quantization, so f32 and
    /// int8 backends at one seed describe the same model.
    pub fn init(cfg: &ModelConfig, seed: u64) -> Result<QuantizedCpuBackend> {
        QuantizedCpuBackend::from_weights(cfg, &init_weights(cfg, seed), RouterMode::TokenChoice)
    }

    /// Load an f32 DTCK checkpoint and quantize on load (`--quant int8`
    /// on the serve/eval CLI paths).
    pub fn from_checkpoint(cfg: &ModelConfig, ck: &Checkpoint) -> Result<QuantizedCpuBackend> {
        CpuBackend::from_checkpoint(cfg, ck)?.quantized()
    }

    /// Switch between token-choice and expert-choice routing.
    pub fn set_router_mode(&mut self, mode: RouterMode) {
        self.router_mode = mode;
    }

    /// The active routing mode.
    pub fn router_mode(&self) -> RouterMode {
        self.router_mode
    }

    /// Run kernels on an explicit pool instead of the process-wide one.
    pub fn set_pool(&mut self, pool: Pool) {
        self.pool = pool;
    }

    /// Convenience for [`QuantizedCpuBackend::set_pool`]: a fresh pool of
    /// `n` threads (`1` = the serial determinism baseline).
    pub fn set_threads(&mut self, n: usize) {
        self.pool = Pool::with_threads(n);
    }

    /// Kernel-thread concurrency this backend currently runs with.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Per-kernel wall-clock accounting.
    pub fn timers(&self) -> &KernelTimers {
        &self.timers
    }

    /// The quantized parameter set (read-only).
    pub fn quant_weights(&self) -> &QuantModelWeights {
        &self.weights
    }

    /// Gather embedding rows for `toks`, dequantizing each row (exact
    /// `i8 × f32` products; the only dequantization on any path).
    fn embed_rows(&self, toks: &[i32], out: &mut Vec<f32>) {
        for &t in toks {
            self.weights.tok_embed.dequant_row_into(t as usize, out);
        }
    }

    /// Hard routing decision for one DTR layer over the full sequence
    /// (mirror of the f32 backend's `decide`).
    fn decide(&self, g: &[f32], n: usize) -> Vec<f32> {
        if self.cfg.variant == Variant::DtrSkip {
            return vec![0.0; n];
        }
        match self.router_mode {
            RouterMode::TokenChoice => kernels::route_decision(g),
            RouterMode::ExpertChoice { capacity } => {
                let g0: Vec<f32> = (0..n).map(|i| g[i * 2]).collect();
                let k = ((capacity * n as f64).ceil() as usize).max(1);
                kernels::topk_mask(&g0, k)
            }
        }
    }

    /// Quantized SwiGLU MLP: `(SiLU(x Wg) * (x Wu)) Wd` with the same
    /// fuse loop as `kernels::swiglu_mlp_par`.
    fn mlp_q8(&self, lw: &QuantLayerWeights, x: &[f32], n: usize) -> Vec<f32> {
        let pool = &self.pool;
        let ff = lw.w_gate.output_dim();
        let mut gate = lw.w_gate.matmul_par(pool, x, n);
        let up = lw.w_up.matmul_par(pool, x, n);
        let grain = (kernels::PAR_CHUNK_FLOPS / (8 * ff).max(1)).max(2);
        pool.run_rows(&mut gate, ff, grain, |row0, rows| {
            let base = row0 * ff;
            for (t, g) in rows.iter_mut().enumerate() {
                *g = kernels::silu(*g) * up[base + t];
            }
        });
        lw.w_down.matmul_par(pool, &gate, n)
    }

    /// Quantized Q/K/V projection + RoPE (mirror of `kernels::qkv_rope_par`).
    fn qkv_rope_q8(
        &self,
        lw: &QuantLayerWeights,
        u: &[f32],
        positions: &[f32],
        n: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let pool = &self.pool;
        let (h, hd) = (self.cfg.n_heads, self.cfg.head_dim());
        let q = kernels::rope_par(
            pool,
            &lw.wq.matmul_par(pool, u, n),
            positions,
            n,
            h,
            hd,
            ROPE_THETA,
        );
        let k = kernels::rope_par(
            pool,
            &lw.wk.matmul_par(pool, u, n),
            positions,
            n,
            h,
            hd,
            ROPE_THETA,
        );
        let v = lw.wv.matmul_par(pool, u, n);
        (q, k, v)
    }

    /// Quantized linear bypass `x Wv Wo` (paper Eq. 5 core).
    fn bypass_q8(&self, lw: &QuantLayerWeights, x: &[f32], n: usize) -> Vec<f32> {
        let v = lw.wv.matmul_par(&self.pool, x, n);
        lw.wo.matmul_par(&self.pool, &v, n)
    }

    /// Row-parallel step over one token per row — the quantized mirror of
    /// `CpuBackend::step_rows` (same causality, cache, logits-mode, and
    /// routing-override contract; see that method's docs).
    fn step_rows(
        &self,
        toks: &[i32],
        positions: &[f32],
        states: &mut [&mut DecodeState],
        cache_of: &[usize],
        logits: LogitsRows,
        route: RouteOverride,
    ) -> Result<RowsOutput> {
        let cfg = &self.cfg;
        let (d, vocab) = (cfg.d_model, cfg.vocab_size);
        let (heads, hd) = (cfg.n_heads, cfg.head_dim());
        let n = toks.len();
        ensure!(n > 0, "step_rows needs at least one row");
        debug_assert_eq!(positions.len(), n);
        debug_assert_eq!(cache_of.len(), n);
        for &t in toks {
            ensure!(
                t >= 0 && (t as usize) < vocab,
                "token id {t} out of range for vocab {vocab}"
            );
        }
        ensure!(
            !matches!(self.router_mode, RouterMode::ExpertChoice { .. }),
            "expert-choice routing needs the full sequence; incremental \
             decode/prefill supports token-choice only"
        );

        let mut x = Vec::with_capacity(n * d);
        self.embed_rows(toks, &mut x);

        let pool = &self.pool;
        let (du, ffu) = (d as u64, cfg.d_ff as u64);
        let dense_eq = dense_equiv_flops(positions, d, cfg.d_ff);
        let mut routed = vec![Vec::with_capacity(cfg.n_layers); n];
        let mut g_attn = vec![Vec::with_capacity(cfg.n_layers); n];
        for (li, lw) in self.weights.layers.iter().enumerate() {
            self.flops.add_dense_equiv(li, dense_eq);
            let u = self
                .timers
                .norm
                .time(|| kernels::rmsnorm_par(pool, &x, &lw.norm1, RMSNORM_EPS));
            let mut mixed = vec![0.0f32; n * d];
            match lw.kind {
                LayerKind::Dense => {
                    self.flops.add_qkvo(li, n as u64 * 8 * du * du);
                    self.flops.add_attn_mix(
                        li,
                        4 * du * attend_context_rows(states, cache_of, li, d),
                    );
                    mixed = self.timers.attention.time(|| {
                        let (q, kk, vv) = self.qkv_rope_q8(lw, &u, positions, n);
                        let ctx =
                            attend_rows(pool, &q, &kk, &vv, states, cache_of, li, d, heads, hd);
                        lw.wo.matmul_par(pool, &ctx, n)
                    });
                    for r in 0..n {
                        routed[r].push(true);
                        g_attn[r].push(1.0);
                    }
                }
                LayerKind::Dtr => {
                    self.flops.add_router(li, n as u64 * (du * du + 2 * du));
                    let g = self
                        .timers
                        .router
                        .time(|| kernels::router_par(pool, &u, &lw.r_w1, &lw.r_w2, n, d, d / 2));
                    let decide = |i: usize| {
                        route == RouteOverride::Router
                            && cfg.variant != Variant::DtrSkip
                            && g[i * 2] > g[i * 2 + 1]
                    };
                    let att_idx: Vec<usize> = (0..n).filter(|&i| decide(i)).collect();
                    let byp_idx: Vec<usize> = (0..n).filter(|&i| !decide(i)).collect();
                    if !att_idx.is_empty() {
                        let rows_cache: Vec<usize> =
                            att_idx.iter().map(|&i| cache_of[i]).collect();
                        self.flops.add_qkvo(li, att_idx.len() as u64 * 8 * du * du);
                        self.flops.add_attn_mix(
                            li,
                            4 * du * attend_context_rows(states, &rows_cache, li, d),
                        );
                        self.timers.attention.time(|| {
                            let u_r = kernels::gather_rows(&u, &att_idx, d);
                            let pos_r: Vec<f32> =
                                att_idx.iter().map(|&i| positions[i]).collect();
                            let (q, kk, vv) = self.qkv_rope_q8(lw, &u_r, &pos_r, att_idx.len());
                            let ctx = attend_rows(
                                pool, &q, &kk, &vv, states, &rows_cache, li, d, heads, hd,
                            );
                            let attn = lw.wo.matmul_par(pool, &ctx, att_idx.len());
                            let g0: Vec<f32> = att_idx.iter().map(|&i| g[i * 2]).collect();
                            kernels::scatter_rows_scaled(&mut mixed, &attn, &att_idx, &g0, d);
                        });
                    }
                    if !byp_idx.is_empty() {
                        self.flops.add_bypass(li, byp_idx.len() as u64 * 4 * du * du);
                        self.timers.bypass.time(|| {
                            let u_b = kernels::gather_rows(&u, &byp_idx, d);
                            let byp = self.bypass_q8(lw, &u_b, byp_idx.len());
                            let g1: Vec<f32> = byp_idx.iter().map(|&i| g[i * 2 + 1]).collect();
                            kernels::scatter_rows_scaled(&mut mixed, &byp, &byp_idx, &g1, d);
                        });
                    }
                    for i in 0..n {
                        routed[i].push(decide(i));
                        g_attn[i].push(g[i * 2]);
                    }
                }
                _ => bail!("unsupported layer kind in quantized CPU backend"),
            }
            for (xv, mv) in x.iter_mut().zip(&mixed) {
                *xv += mv;
            }
            let h2 = self
                .timers
                .norm
                .time(|| kernels::rmsnorm_par(pool, &x, &lw.norm2, RMSNORM_EPS));
            self.flops.add_mlp(li, n as u64 * 6 * du * ffu);
            let mlp = self.timers.mlp.time(|| self.mlp_q8(lw, &h2, n));
            for (xv, mv) in x.iter_mut().zip(&mlp) {
                *xv += mv;
            }
        }

        let logit_rows = match logits {
            LogitsRows::None => 0,
            LogitsRows::Last => 1,
            LogitsRows::All => n,
        };
        self.flops
            .add_unembed(logit_rows as u64 * 2 * du * vocab as u64);
        let logits = self.timers.unembed.time(|| match logits {
            LogitsRows::None => Vec::new(),
            LogitsRows::Last => {
                let xn = kernels::rmsnorm_par(
                    pool,
                    &x[(n - 1) * d..n * d],
                    &self.weights.out_norm,
                    RMSNORM_EPS,
                );
                self.weights.unembed.matmul_par(pool, &xn, 1)
            }
            LogitsRows::All => {
                let xn = kernels::rmsnorm_par(pool, &x, &self.weights.out_norm, RMSNORM_EPS);
                self.weights.unembed.matmul_par(pool, &xn, n)
            }
        });
        for &c in cache_of {
            states[c].position += 1;
        }
        Ok(RowsOutput {
            logits,
            routed,
            g_attn,
        })
    }

    /// Single-sequence forward — the quantized mirror of
    /// `CpuBackend::forward_seq`.
    fn forward_seq(&self, tokens: &[i32]) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let cfg = &self.cfg;
        let (d, vocab) = (cfg.d_model, cfg.vocab_size);
        let (heads, hd) = (cfg.n_heads, cfg.head_dim());
        let n = tokens.len();
        let n_layers = cfg.n_layers;
        let positions: Vec<f32> = (0..n).map(|i| i as f32).collect();

        for &t in tokens {
            ensure!(
                t >= 0 && (t as usize) < vocab,
                "token id {t} out of range for vocab {vocab}"
            );
        }
        let mut x = Vec::with_capacity(n * d);
        self.embed_rows(tokens, &mut x);

        let pool = &self.pool;
        let (du, ffu) = (d as u64, cfg.d_ff as u64);
        let dense_eq = dense_equiv_flops(&positions, d, cfg.d_ff);
        let mut route = vec![0.0f32; n_layers * n];
        let mut g_attn = vec![0.0f32; n_layers * n];
        for (li, lw) in self.weights.layers.iter().enumerate() {
            self.flops.add_dense_equiv(li, dense_eq);
            let u = self
                .timers
                .norm
                .time(|| kernels::rmsnorm_par(pool, &x, &lw.norm1, RMSNORM_EPS));
            let (mixed, delta, g0): (Vec<f32>, Vec<f32>, Vec<f32>) = match lw.kind {
                LayerKind::Dense => {
                    self.flops.add_qkvo(li, n as u64 * 8 * du * du);
                    self.flops
                        .add_attn_mix(li, 4 * du * (n as u64 * (n as u64 + 1) / 2));
                    let attn = self.timers.attention.time(|| {
                        let (q, kk, vv) = self.qkv_rope_q8(lw, &u, &positions, n);
                        let ctx = kernels::dense_attention_par(pool, &q, &kk, &vv, n, heads, hd);
                        lw.wo.matmul_par(pool, &ctx, n)
                    });
                    (attn, vec![1.0; n], vec![1.0; n])
                }
                LayerKind::Dtr => {
                    self.flops.add_router(li, n as u64 * (du * du + 2 * du));
                    let g = self
                        .timers
                        .router
                        .time(|| kernels::router_par(pool, &u, &lw.r_w1, &lw.r_w2, n, d, d / 2));
                    let delta = self.decide(&g, n);
                    // Measured = executed: this training-shape path runs
                    // QKVO and the bypass for *every* row before the
                    // soft-score select (unlike the gathered serve path),
                    // so the counters record that dense-like projection
                    // cost; only attn_mix shrinks with routing here.
                    let (mut att, mut ctx_total) = (0u64, 0u64);
                    for &dv in &delta {
                        if dv > 0.5 {
                            att += 1;
                            ctx_total += att;
                        }
                    }
                    self.flops.add_qkvo(li, n as u64 * 8 * du * du);
                    self.flops.add_attn_mix(li, 4 * du * ctx_total);
                    self.flops.add_bypass(li, n as u64 * 4 * du * du);
                    let mixed = self.timers.attention.time(|| {
                        // routed attention for selected tokens, bypass for
                        // the rest, soft-score path select (Eqs. 3–5) —
                        // the quantized form of kernels::dtr_token_mix_par
                        let (q, kk, vv) = self.qkv_rope_q8(lw, &u, &positions, n);
                        let ctx = kernels::routed_attention_par(
                            pool, &q, &kk, &vv, &delta, n, heads, hd,
                        );
                        let attn_out = lw.wo.matmul_par(pool, &ctx, n);
                        let byp = self.bypass_q8(lw, &u, n);
                        let mut update = vec![0.0f32; n * d];
                        for i in 0..n {
                            let (w, src) = if delta[i] > 0.5 {
                                (g[i * 2], &attn_out)
                            } else {
                                (g[i * 2 + 1], &byp)
                            };
                            for j in 0..d {
                                update[i * d + j] = w * src[i * d + j];
                            }
                        }
                        update
                    });
                    let g0 = (0..n).map(|i| g[i * 2]).collect();
                    (mixed, delta, g0)
                }
                _ => bail!("unsupported layer kind in quantized CPU backend"),
            };
            for (xv, mv) in x.iter_mut().zip(&mixed) {
                *xv += mv;
            }
            let h2 = self
                .timers
                .norm
                .time(|| kernels::rmsnorm_par(pool, &x, &lw.norm2, RMSNORM_EPS));
            self.flops.add_mlp(li, n as u64 * 6 * du * ffu);
            let mlp = self.timers.mlp.time(|| self.mlp_q8(lw, &h2, n));
            for (xv, mv) in x.iter_mut().zip(&mlp) {
                *xv += mv;
            }
            route[li * n..(li + 1) * n].copy_from_slice(&delta);
            g_attn[li * n..(li + 1) * n].copy_from_slice(&g0);
        }

        self.flops.add_unembed(n as u64 * 2 * du * vocab as u64);
        let logits = self.timers.unembed.time(|| {
            let xn = kernels::rmsnorm_par(pool, &x, &self.weights.out_norm, RMSNORM_EPS);
            self.weights.unembed.matmul_par(pool, &xn, n)
        });
        Ok((logits, route, g_attn))
    }
}

impl Backend for QuantizedCpuBackend {
    fn name(&self) -> &'static str {
        "cpu-int8"
    }

    fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn kernel_timings(&self) -> Option<Json> {
        Some(self.timers.snapshot_with_ctx(self.pool.kernel_ctx()))
    }

    fn flop_counters(&self) -> Option<&FlopCounters> {
        Some(&self.flops)
    }

    fn weight_bytes(&self) -> WeightBytes {
        self.weights.weight_bytes()
    }

    fn forward(&self, tokens: &Tensor) -> Result<ForwardOutput> {
        ensure!(
            tokens.shape.len() == 2,
            "forward expects [B, S] tokens, got shape {:?}",
            tokens.shape
        );
        let (b, s) = (tokens.shape[0], tokens.shape[1]);
        let n_layers = self.cfg.n_layers;
        let vocab = self.cfg.vocab_size;
        let ids = tokens.as_i32();

        let mut logits = Vec::with_capacity(b * s * vocab);
        let mut route = Vec::with_capacity(b * n_layers * s);
        let mut g_attn = Vec::with_capacity(b * n_layers * s);
        for bi in 0..b {
            let (lg, rt, ga) = self.forward_seq(&ids[bi * s..(bi + 1) * s])?;
            logits.extend_from_slice(&lg);
            route.extend_from_slice(&rt);
            g_attn.extend_from_slice(&ga);
        }
        let mut attn_frac = vec![0.0f64; n_layers];
        for bi in 0..b {
            for l in 0..n_layers {
                let row = &route[(bi * n_layers + l) * s..(bi * n_layers + l + 1) * s];
                attn_frac[l] += row.iter().map(|&r| r as f64).sum::<f64>() / (b * s) as f64;
            }
        }
        Ok(ForwardOutput {
            logits: Tensor::f32(vec![b, s, vocab], logits),
            route: Tensor::f32(vec![b, n_layers, s], route),
            g_attn: Tensor::f32(vec![b, n_layers, s], g_attn),
            attn_frac,
        })
    }

    fn begin_decode(&self) -> DecodeState {
        DecodeState::new(self.cfg.n_layers)
    }

    /// One-token decode via the shared row-step core (a single row is
    /// exactly the sequential decode semantics: same kernels, same cache
    /// appends, same position bump; mirror of the f32 backend's
    /// canonical step — [`RouteOverride::ForceBypass`] is the
    /// speculative draft pass).
    fn decode_step_routed(
        &self,
        state: &mut DecodeState,
        token: i32,
        route: RouteOverride,
    ) -> Result<StepOutput> {
        let positions = [state.position as f32];
        let mut slab = [&mut *state];
        let RowsOutput {
            logits,
            mut routed,
            mut g_attn,
        } = self.step_rows(&[token], &positions, &mut slab, &[0], LogitsRows::All, route)?;
        Ok(StepOutput {
            logits: Tensor::f32(vec![self.cfg.vocab_size], logits),
            routed: routed.pop().unwrap(),
            g_attn: g_attn.pop().unwrap(),
        })
    }

    /// Batched single-sequence multi-row decode — the speculative
    /// verification pass (mirror of the f32 backend's override;
    /// bit-identical to a sequential [`Backend::decode_step`] loop).
    fn decode_rows(&self, state: &mut DecodeState, tokens: &[i32]) -> Result<Vec<StepOutput>> {
        ensure!(!tokens.is_empty(), "decode_rows needs at least one token");
        let vocab = self.cfg.vocab_size;
        for &t in tokens {
            ensure!(
                t >= 0 && (t as usize) < vocab,
                "token id {t} out of range for vocab {vocab}"
            );
        }
        ensure!(
            !matches!(self.router_mode, RouterMode::ExpertChoice { .. }),
            "expert-choice routing needs the full sequence; decode supports token-choice only"
        );
        let n = tokens.len();
        let positions: Vec<f32> = (0..n).map(|i| (state.position + i) as f32).collect();
        let cache_of = vec![0usize; n];
        let mut slab = [&mut *state];
        let RowsOutput {
            logits,
            routed,
            g_attn,
        } = self.step_rows(
            tokens,
            &positions,
            &mut slab,
            &cache_of,
            LogitsRows::All,
            RouteOverride::Router,
        )?;
        let mut outs = Vec::with_capacity(n);
        for (i, (r, ga)) in routed.into_iter().zip(g_attn).enumerate() {
            outs.push(StepOutput {
                logits: Tensor::f32(vec![vocab], logits[i * vocab..(i + 1) * vocab].to_vec()),
                routed: r,
                g_attn: ga,
            });
        }
        Ok(outs)
    }

    /// Vectorized multi-sequence decode (mirror of the f32 backend's
    /// override; bit-identical to per-sequence [`Backend::decode_step`]).
    fn decode_batch(
        &self,
        states: &mut [&mut DecodeState],
        tokens: &[i32],
    ) -> Result<Vec<StepOutput>> {
        ensure!(
            states.len() == tokens.len(),
            "decode_batch: {} states vs {} tokens",
            states.len(),
            tokens.len()
        );
        let b = states.len();
        if b == 0 {
            return Ok(Vec::new());
        }
        let positions: Vec<f32> = states.iter().map(|s| s.position as f32).collect();
        let cache_of: Vec<usize> = (0..b).collect();
        let RowsOutput {
            logits,
            routed,
            g_attn,
        } = self.step_rows(
            tokens,
            &positions,
            states,
            &cache_of,
            LogitsRows::All,
            RouteOverride::Router,
        )?;
        let vocab = self.cfg.vocab_size;
        let mut outs = Vec::with_capacity(b);
        for (i, (r, ga)) in routed.into_iter().zip(g_attn).enumerate() {
            outs.push(StepOutput {
                logits: Tensor::f32(vec![vocab], logits[i * vocab..(i + 1) * vocab].to_vec()),
                routed: r,
                g_attn: ga,
            });
        }
        Ok(outs)
    }

    /// Streaming chunked prefill keeping every chunk's per-row routing
    /// telemetry (mirror of the f32 backend's override; also serves
    /// [`Backend::prefill_chunked`] through the trait's default
    /// adapter — one chunk loop, not two).
    fn prefill_rows(
        &self,
        state: &mut DecodeState,
        tokens: &[i32],
        chunk: usize,
    ) -> Result<PrefillRows> {
        ensure!(!tokens.is_empty(), "prefill needs at least one token");
        let vocab = self.cfg.vocab_size;
        for &t in tokens {
            ensure!(
                t >= 0 && (t as usize) < vocab,
                "token id {t} out of range for vocab {vocab}"
            );
        }
        ensure!(
            !matches!(self.router_mode, RouterMode::ExpertChoice { .. }),
            "expert-choice routing needs the full sequence; prefill supports token-choice only"
        );
        let chunk = chunk.max(1);
        let n_chunks = tokens.len().div_ceil(chunk);
        let mut routed = Vec::with_capacity(tokens.len());
        let mut g_attn = Vec::with_capacity(tokens.len());
        let mut logits = Vec::new();
        for (ci, ck) in tokens.chunks(chunk).enumerate() {
            let positions: Vec<f32> =
                (0..ck.len()).map(|i| (state.position + i) as f32).collect();
            let cache_of = vec![0usize; ck.len()];
            let mut slab = [&mut *state];
            let mode = if ci + 1 == n_chunks {
                LogitsRows::Last
            } else {
                LogitsRows::None
            };
            let out =
                self.step_rows(ck, &positions, &mut slab, &cache_of, mode, RouteOverride::Router)?;
            routed.extend(out.routed);
            g_attn.extend(out.g_attn);
            logits = out.logits;
        }
        Ok(PrefillRows {
            last: StepOutput {
                logits: Tensor::f32(vec![vocab], logits),
                routed: routed.last().unwrap().clone(),
                g_attn: g_attn.last().unwrap().clone(),
            },
            routed,
            g_attn,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn xs(variant: Variant) -> ModelConfig {
        ModelConfig::preset("xs", variant)
    }

    #[test]
    fn quant_matrix_roundtrip_error_is_bounded_per_row() {
        let mut rng = Rng::new(5);
        let (k, m) = (48usize, 24usize);
        let w: Vec<f32> = (0..k * m).map(|_| rng.normal() as f32 * 0.3).collect();
        let qm = QuantMatrix::quantize(&w, k, m);
        let deq = qm.dequantize();
        for j in 0..m {
            let half = qm.scales()[j] * 0.5;
            for kk in 0..k {
                let e = (deq[kk * m + j] - w[kk * m + j]).abs();
                assert!(e <= half + 1e-7, "col {j}: error {e} > scale/2 {half}");
            }
        }
    }

    #[test]
    fn row_major_and_transposed_quantization_agree() {
        let mut rng = Rng::new(6);
        let (v, d) = (10usize, 8usize);
        // tok_embed stored [V, d]: row-major quantization of it must equal
        // transposed quantization of its [d, V] transpose.
        let e: Vec<f32> = (0..v * d).map(|_| rng.normal() as f32 * 0.1).collect();
        let mut et = vec![0.0f32; d * v];
        for r in 0..v {
            for c in 0..d {
                et[c * v + r] = e[r * d + c];
            }
        }
        let a = QuantMatrix::quantize_row_major(&e, v, d);
        let b = QuantMatrix::quantize(&et, d, v);
        assert_eq!(a.scales(), b.scales());
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn rejects_unsupported_variants() {
        assert!(QuantizedCpuBackend::init(&xs(Variant::Mod), 0).is_err());
        assert!(QuantizedCpuBackend::init(&xs(Variant::DtrBilayer), 0).is_ok());
    }

    #[test]
    fn weight_bytes_compression_exceeds_gate() {
        for preset in ["xs", "tiny"] {
            let cfg = ModelConfig::preset(preset, Variant::DtrBilayer);
            let be = QuantizedCpuBackend::init(&cfg, 0).unwrap();
            let wb = be.weight_bytes();
            assert_eq!(wb.f32_equiv, 4 * cfg.param_count(), "{preset} f32 bytes");
            assert!(
                wb.compression() >= 3.5,
                "{preset}: compression {:.3} below the 3.5x gate",
                wb.compression()
            );
        }
    }

    #[test]
    fn forward_is_finite_and_routes_like_a_dtr_model() {
        let be = QuantizedCpuBackend::init(&xs(Variant::DtrBilayer), 3).unwrap();
        let tokens = Tensor::i32(vec![1, 16], (0..16).map(|i| i * 5 % 256).collect());
        let out = be.forward(&tokens).unwrap();
        assert!(out.logits.as_f32().iter().all(|x| x.is_finite()));
        for (l, kind) in be.config().layout_string().chars().enumerate() {
            if kind == 'T' {
                assert_eq!(out.attn_frac[l], 1.0, "dense layer {l}");
            } else {
                assert!(out.attn_frac[l] < 1.0, "DTR layer {l} should bypass some");
            }
        }
    }

    #[test]
    fn dtr_skip_routes_nothing() {
        let be = QuantizedCpuBackend::init(&xs(Variant::DtrSkip), 1).unwrap();
        let tokens = Tensor::i32(vec![1, 8], (0..8).collect());
        let out = be.forward(&tokens).unwrap();
        for (l, kind) in be.config().layout_string().chars().enumerate() {
            if kind == 'D' {
                assert_eq!(out.attn_frac[l], 0.0, "dtr_skip layer {l} must bypass");
            }
        }
    }

    #[test]
    fn checkpoint_quantize_on_load_matches_direct_quantization() {
        let f32_be = CpuBackend::init(&xs(Variant::DtrBilayer), 7).unwrap();
        let via_ck =
            QuantizedCpuBackend::from_checkpoint(f32_be.config(), &f32_be.to_checkpoint())
                .unwrap();
        let direct = f32_be.quantized().unwrap();
        let tokens = Tensor::i32(vec![1, 12], (0..12).map(|i| i * 3 % 256).collect());
        assert_eq!(
            via_ck.forward(&tokens).unwrap().logits,
            direct.forward(&tokens).unwrap().logits,
            "quantize-on-load must equal direct quantization bitwise"
        );
    }

    /// Build a synthetic single-layer ForwardOutput with the given hard
    /// decisions and soft scores (the gate only reads route/g_attn).
    fn synth_out(route: Vec<f32>, g: Vec<f32>) -> ForwardOutput {
        let n = route.len();
        ForwardOutput {
            logits: Tensor::f32(vec![1, n, 1], vec![0.0; n]),
            route: Tensor::f32(vec![1, 1, n], route),
            g_attn: Tensor::f32(vec![1, 1, n], g),
            attn_frac: vec![0.0],
        }
    }

    #[test]
    fn routing_gate_semantics() {
        let n = 200usize;
        // f32 reference: alternate decisions; half decisive, half near-tie.
        let route: Vec<f32> = (0..n).map(|i| (i % 2) as f32).collect();
        let g: Vec<f32> = (0..n)
            .map(|i| {
                let decisive = i % 4 < 2;
                match (i % 2 == 1, decisive) {
                    (true, true) => 0.9,    // routed, decisive
                    (true, false) => 0.501, // routed, near-tie
                    (false, true) => 0.1,   // bypassed, decisive
                    (false, false) => 0.499,
                }
            })
            .collect();
        let a = synth_out(route.clone(), g.clone());

        // identical decisions pass with zero flips
        let eq = check_routing_equivalence(&a, &a).unwrap();
        assert_eq!(eq.flips, 0);
        assert!(eq.min_f32_margin < 0.01);

        // one near-tie flip (0.5% of 200 DTR decisions) is inside budget
        let mut r2 = route.clone();
        r2[3] = 1.0 - r2[3]; // i=3: routed near-tie (g = 0.501)
        let eq = check_routing_equivalence(&a, &synth_out(r2, g.clone())).unwrap();
        assert_eq!(eq.flips, 1);
        assert_eq!(eq.decisive_flips, 0);
        assert_eq!(eq.dtr_decisions, n, "every synthetic decision has g < 1");

        // a single decisive flip is rejected outright
        let mut r3 = route.clone();
        r3[1] = 1.0 - r3[1]; // i=1: routed decisive (g = 0.9)
        assert!(check_routing_equivalence(&a, &synth_out(r3, g.clone())).is_err());

        // too many near-tie flips trip the fraction budget
        let mut r4 = route.clone();
        for i in (0..n).filter(|i| i % 4 == 3).take(11) {
            r4[i] = 1.0 - r4[i]; // eleven near-tie flips = 5.5% > 5%
        }
        assert!(check_routing_equivalence(&a, &synth_out(r4, g)).is_err());
    }
}
