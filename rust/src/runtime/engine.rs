//! Engine: PJRT client + compiled-executable cache.
//!
//! One `Engine` per process. Artifacts compile lazily on first use and are
//! cached by name. Executions go through `Executable::call`, which checks
//! arity, packs host tensors into literals, runs, and unpacks the result
//! tuple (aot.py lowers with `return_tuple=True`).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactSpec, Manifest};
use super::tensor::Tensor;

/// Process-wide runtime: PJRT CPU client + manifest + executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    /// Artifact inventory loaded from `manifest.json`.
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl Engine {
    /// Create an engine over the artifact directory (see
    /// [`crate::artifacts_dir`]). Compiles nothing yet.
    pub fn new(dir: &std::path::Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// PJRT platform name (cpu/gpu/tpu).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact by manifest name (cached).
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(Arc::clone(e));
        }
        let spec = self.manifest.get(name)?.clone();
        let path = self.manifest.hlo_path(&spec);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {name}"))?;
        let exe = Arc::new(Executable {
            name: name.to_string(),
            spec,
            exe,
            compile_s: t0.elapsed().as_secs_f64(),
        });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), Arc::clone(&exe));
        Ok(exe)
    }
}

/// A compiled artifact, callable over host tensors.
pub struct Executable {
    /// Artifact name (manifest key).
    pub name: String,
    /// Shapes/layout contract for this executable.
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    /// Compile wall-clock seconds (one-time, per process).
    pub compile_s: f64,
}

impl Executable {
    /// Execute with host tensors; returns the decomposed output tuple.
    pub fn call(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let lits = self.pack(inputs)?;
        let outs = self.call_literals(&lits)?;
        outs.iter().map(Tensor::from_literal).collect()
    }

    /// Pack host tensors into literals, validating arity and shapes.
    pub fn pack(&self, inputs: &[Tensor]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        inputs
            .iter()
            .enumerate()
            .map(|(i, t)| {
                let want = &self.spec.inputs[i].shape;
                if &t.shape != want {
                    bail!(
                        "{}: input {i} shape {:?} != manifest {:?}",
                        self.name,
                        t.shape,
                        want
                    );
                }
                t.to_literal()
            })
            .collect()
    }

    /// Execute with pre-packed literals (hot-path variant: callers reuse
    /// literal buffers across steps where inputs don't change).
    pub fn call_literals(&self, lits: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(lits)?;
        let tuple = result[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }

    /// Like [`Self::call_literals`] but over borrowed literals — lets the
    /// trainer/serving loops keep resident state (weights, KV cache) and
    /// pass references each step without cloning.
    pub fn call_literals_ref(&self, lits: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<&xla::Literal>(lits)?;
        let tuple = result[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }

    /// Execute and keep outputs as device buffers (for param-resident
    /// loops: feed these straight back in via [`Self::call_buffers`]).
    pub fn call_buffers(&self, bufs: &[xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        let mut result = self.exe.execute_b::<xla::PjRtBuffer>(bufs)?;
        Ok(result.remove(0))
    }

    /// Number of input literals the executable expects.
    pub fn n_inputs(&self) -> usize {
        self.spec.inputs.len()
    }

    /// Number of output literals the executable produces.
    pub fn n_outputs(&self) -> usize {
        self.spec.outputs.len()
    }
}

/// Convert a literal tuple element count mismatch into a readable error.
pub fn expect_outputs(outs: &[Tensor], n: usize, what: &str) -> Result<()> {
    if outs.len() != n {
        bail!("{what}: expected {n} outputs, got {}", outs.len());
    }
    Ok(())
}
