//! Page-view KV storage — the single surface attention reads cached K/V
//! through.
//!
//! Kernels never touch raw cache rows: they receive a per-layer list of
//! [`KvPageRef`]s (in append order) from [`KvCache::view`] and walk the
//! pages like one contiguous slab. Two implementations sit behind the
//! [`KvCache`] enum:
//!
//! * [`ResidentKv`] — today's flat `Vec<f32>` per layer, exposed as a
//!   single page. Zero-cost and bitwise-identical to the pre-paging
//!   layout by construction (the view *is* the slab).
//! * [`BoundedKv`] — fixed-size pages with a resident-page budget, LRU
//!   eviction and spill-to-disk offload. Eviction moves cold pages to a
//!   spill file; it never drops them from attention, so the visible key
//!   order — and therefore every logit bit — is identical to the
//!   resident slab. `rust/tests/longctx_smoke.rs` pins this bitwise.
//!
//! # Determinism contract
//!
//! A view lists pages in append order and concatenating their rows
//! reproduces the flat slab exactly. [`decode_attention_paged`]
//! (rust/src/runtime/cpu/kernels.rs) folds logits in page order with a
//! single softmax, so paged attention is bit-identical to the flat
//! kernel for any page size, budget, or eviction history. Spilled pages
//! round-trip through little-endian `f32` bytes — exact.
//!
//! # Eviction policy
//!
//! One global LRU clock stamps pages on every pin/append.
//! [`KvCache::pin_layer`] faults a whole layer resident before attention
//! reads it (attention needs the full routed prefix), evicting
//! least-recently-used pages of *other* layers while the resident count
//! exceeds the budget. The budget therefore bounds the high-water mark
//! at roughly one layer's working set plus slack — memory scales with
//! `max_layer_pages`, not `n_layers * max_layer_pages`. If a single
//! layer alone exceeds the budget the cache keeps that layer resident
//! (correctness over the cap) and the high-water mark records the
//! overshoot.
//!
//! # Ownership
//!
//! Each `DecodeState` owns its `KvCache`; the spill file (created
//! lazily under the OS temp dir) is owned by the cache and unlinked on
//! drop. Spill I/O failures panic — attention cannot half-read a page.
//!
//! `KvPool` (coordinator/kv_cache.rs) stays the engine-side *accountant*
//! — it derives page counts from the same per-layer lengths this storage
//! reports, it does not own rows.

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

/// Distinguishes spill files of concurrently-live caches in one process.
static SPILL_ID: AtomicU64 = AtomicU64::new(0);

/// A borrowed page of cached K/V rows: `k`/`v` are row-major
/// `[rows, d]` slices of equal length. Pages concatenate (in view
/// order) to the flat cache slab.
#[derive(Debug, Clone, Copy)]
pub struct KvPageRef<'a> {
    /// Cached key rows, `rows * d` floats.
    pub k: &'a [f32],
    /// Cached value rows, `rows * d` floats.
    pub v: &'a [f32],
}

impl KvPageRef<'_> {
    /// Number of cached rows in this page.
    pub fn rows(&self, d: usize) -> usize {
        debug_assert_eq!(self.k.len(), self.v.len());
        self.k.len() / d
    }
}

/// KV storage behind `DecodeState` — resident slab or bounded/paged.
#[derive(Debug, Clone)]
pub enum KvCache {
    /// Flat per-layer slabs, always resident (the default).
    Resident(ResidentKv),
    /// Paged storage with an LRU resident budget and disk offload.
    Bounded(BoundedKv),
}

impl KvCache {
    /// Unbounded resident-slab cache (bitwise the pre-paging layout).
    pub fn resident(n_layers: usize) -> KvCache {
        KvCache::Resident(ResidentKv {
            keys: vec![Vec::new(); n_layers],
            values: vec![Vec::new(); n_layers],
        })
    }

    /// Bounded cache: at most `budget_pages` pages resident (high-water
    /// mark, see module docs), pages of `page_rows` rows, spill file in
    /// `spill_dir` (OS temp dir when `None`).
    pub fn bounded(
        n_layers: usize,
        d: usize,
        page_rows: usize,
        budget_pages: usize,
        spill_dir: Option<PathBuf>,
    ) -> KvCache {
        KvCache::Bounded(BoundedKv::new(n_layers, d, page_rows, budget_pages, spill_dir))
    }

    /// Layer count.
    pub fn n_layers(&self) -> usize {
        match self {
            KvCache::Resident(r) => r.keys.len(),
            KvCache::Bounded(b) => b.layers.len(),
        }
    }

    /// Cached rows at layer `li` (`d` = row width in floats).
    pub fn len(&self, li: usize, d: usize) -> usize {
        match self {
            KvCache::Resident(r) => r.keys[li].len() / d,
            KvCache::Bounded(b) => b.layer_rows(li),
        }
    }

    /// Cached rows per layer.
    pub fn lens(&self, d: usize) -> Vec<usize> {
        (0..self.n_layers()).map(|li| self.len(li, d)).collect()
    }

    /// Append one K/V row (`d` floats each) to layer `li`.
    pub fn append_row(&mut self, li: usize, k: &[f32], v: &[f32]) {
        debug_assert_eq!(k.len(), v.len());
        match self {
            KvCache::Resident(r) => {
                r.keys[li].extend_from_slice(k);
                r.values[li].extend_from_slice(v);
            }
            KvCache::Bounded(b) => b.append_row(li, k, v),
        }
    }

    /// Truncate every layer to `lens[li]` rows (speculative rollback).
    pub fn truncate(&mut self, lens: &[usize], d: usize) {
        match self {
            KvCache::Resident(r) => {
                for (li, &len) in lens.iter().enumerate() {
                    r.keys[li].truncate(len * d);
                    r.values[li].truncate(len * d);
                }
            }
            KvCache::Bounded(b) => b.truncate(lens, d),
        }
    }

    /// Fault layer `li` fully resident ahead of an attention read,
    /// evicting LRU pages of other layers past the budget. No-op for
    /// the resident slab.
    pub fn pin_layer(&mut self, li: usize) {
        if let KvCache::Bounded(b) = self {
            b.pin_layer(li);
        }
    }

    /// Page views over layer `li` in append order. Every page must be
    /// resident — call [`KvCache::pin_layer`] first on bounded caches.
    pub fn view(&self, li: usize, d: usize) -> Vec<KvPageRef<'_>> {
        match self {
            KvCache::Resident(r) => {
                debug_assert_eq!(r.keys[li].len() % d, 0);
                vec![KvPageRef {
                    k: &r.keys[li],
                    v: &r.values[li],
                }]
            }
            KvCache::Bounded(b) => b.view(li),
        }
    }

    /// Flat per-layer `(keys, values)` copies — the test-equality and
    /// migration surface (reads spilled pages back; bit-exact).
    pub fn snapshot(&self) -> Vec<(Vec<f32>, Vec<f32>)> {
        match self {
            KvCache::Resident(r) => r
                .keys
                .iter()
                .zip(&r.values)
                .map(|(k, v)| (k.clone(), v.clone()))
                .collect(),
            KvCache::Bounded(b) => b.snapshot(),
        }
    }

    /// Resident-page budget (`None` for the unpaged resident slab).
    pub fn budget_pages(&self) -> Option<usize> {
        match self {
            KvCache::Resident(_) => None,
            KvCache::Bounded(b) => Some(b.budget),
        }
    }

    /// Currently resident pages (`None` for the unpaged resident slab).
    pub fn resident_pages(&self) -> Option<usize> {
        match self {
            KvCache::Resident(_) => None,
            KvCache::Bounded(b) => Some(b.resident),
        }
    }

    /// Resident-page high-water mark (0 for the resident slab — it has
    /// no page accounting).
    pub fn resident_pages_peak(&self) -> usize {
        match self {
            KvCache::Resident(_) => 0,
            KvCache::Bounded(b) => b.resident_peak,
        }
    }
}

/// Flat per-layer K/V slabs — the pre-paging layout, one "page" per
/// layer covering everything.
#[derive(Debug, Clone)]
pub struct ResidentKv {
    keys: Vec<Vec<f32>>,
    values: Vec<Vec<f32>>,
}

/// Where a bounded page's rows currently live.
#[derive(Debug)]
enum PageData {
    Resident { k: Vec<f32>, v: Vec<f32> },
    Spilled { slot: u64 },
}

#[derive(Debug)]
struct Page {
    /// Valid rows (`<= page_rows`; the last page of a layer fills up).
    rows: usize,
    /// LRU stamp from the cache-wide clock.
    last_used: u64,
    data: PageData,
}

/// Paged KV with an LRU resident budget and spill-to-disk offload.
#[derive(Debug)]
pub struct BoundedKv {
    d: usize,
    page_rows: usize,
    budget: usize,
    layers: Vec<Vec<Page>>,
    clock: u64,
    resident: usize,
    resident_peak: usize,
    spill: Spill,
}

impl BoundedKv {
    fn new(
        n_layers: usize,
        d: usize,
        page_rows: usize,
        budget_pages: usize,
        spill_dir: Option<PathBuf>,
    ) -> BoundedKv {
        assert!(d > 0 && page_rows > 0, "bounded KV needs d > 0 and page_rows > 0");
        assert!(budget_pages > 0, "bounded KV needs a budget of at least one page");
        BoundedKv {
            d,
            page_rows,
            budget: budget_pages,
            layers: (0..n_layers).map(|_| Vec::new()).collect(),
            clock: 0,
            resident: 0,
            resident_peak: 0,
            spill: Spill::new(spill_dir, page_rows * d),
        }
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    fn layer_rows(&self, li: usize) -> usize {
        self.layers[li].iter().map(|p| p.rows).sum()
    }

    fn note_resident(&mut self, added: usize) {
        self.resident += added;
        self.resident_peak = self.resident_peak.max(self.resident);
    }

    /// Reload page `pi` of layer `li` if spilled, making room *first*
    /// so the resident count never overshoots the budget.
    fn fault_page(&mut self, li: usize, pi: usize, now: u64) {
        self.layers[li][pi].last_used = now;
        if let PageData::Spilled { slot } = self.layers[li][pi].data {
            self.make_room(li);
            let (k, v) = self.spill.read(slot, self.layers[li][pi].rows * self.d);
            self.spill.free(slot);
            self.layers[li][pi].data = PageData::Resident { k, v };
            self.note_resident(1);
        }
    }

    /// Write page `pi` of layer `li` out and drop its resident rows.
    fn spill_page(&mut self, li: usize, pi: usize) {
        let rows = self.layers[li][pi].rows;
        if let PageData::Resident { k, v } =
            std::mem::replace(&mut self.layers[li][pi].data, PageData::Spilled { slot: 0 })
        {
            debug_assert_eq!(k.len(), rows * self.d);
            let slot = self.spill.alloc();
            self.spill.write(slot, &k, &v);
            self.layers[li][pi].data = PageData::Spilled { slot };
            self.resident -= 1;
        }
    }

    /// Make room for one more resident page by evicting globally-LRU
    /// resident pages, never touching layer `keep_layer` (it is being
    /// read or appended). Stops early if nothing outside `keep_layer`
    /// is evictable — a layer whose own working set exceeds the budget
    /// stays resident (correctness over the cap, see module docs).
    fn make_room(&mut self, keep_layer: usize) {
        while self.resident >= self.budget {
            let mut victim: Option<(usize, usize, u64)> = None;
            for (li, pages) in self.layers.iter().enumerate() {
                if li == keep_layer {
                    continue;
                }
                for (pi, p) in pages.iter().enumerate() {
                    if matches!(p.data, PageData::Resident { .. })
                        && victim.map_or(true, |(_, _, t)| p.last_used < t)
                    {
                        victim = Some((li, pi, p.last_used));
                    }
                }
            }
            match victim {
                Some((li, pi, _)) => self.spill_page(li, pi),
                None => break, // keep_layer alone exceeds the budget
            }
        }
    }

    fn pin_layer(&mut self, li: usize) {
        let now = self.tick();
        for pi in 0..self.layers[li].len() {
            self.fault_page(li, pi, now);
        }
    }

    fn append_row(&mut self, li: usize, k: &[f32], v: &[f32]) {
        debug_assert_eq!(k.len(), self.d);
        let now = self.tick();
        let needs_new = match self.layers[li].last() {
            Some(p) => p.rows >= self.page_rows,
            None => true,
        };
        if needs_new {
            self.make_room(li);
            self.layers[li].push(Page {
                rows: 0,
                last_used: now,
                data: PageData::Resident {
                    k: Vec::with_capacity(self.page_rows * self.d),
                    v: Vec::with_capacity(self.page_rows * self.d),
                },
            });
            self.note_resident(1);
        } else {
            // The tail page may have been evicted since the last append
            // (e.g. while other layers were pinned) — fault it back.
            let pi = self.layers[li].len() - 1;
            self.fault_page(li, pi, now);
        }
        let page = self.layers[li].last_mut().unwrap();
        page.last_used = now;
        page.rows += 1;
        match &mut page.data {
            PageData::Resident { k: pk, v: pv } => {
                pk.extend_from_slice(k);
                pv.extend_from_slice(v);
            }
            PageData::Spilled { .. } => unreachable!("tail page faulted above"),
        }
    }

    fn truncate(&mut self, lens: &[usize], d: usize) {
        debug_assert_eq!(d, self.d);
        for (li, &target) in lens.iter().enumerate() {
            let mut start = 0usize;
            let mut keep = 0usize;
            for p in &self.layers[li] {
                if start >= target {
                    break;
                }
                keep += 1;
                start += p.rows;
            }
            // Drop whole pages past the target.
            while self.layers[li].len() > keep {
                let p = self.layers[li].pop().unwrap();
                match p.data {
                    PageData::Resident { .. } => self.resident -= 1,
                    PageData::Spilled { slot } => self.spill.free(slot),
                }
            }
            // Trim the now-last page; rows within a page are in append
            // order, so a prefix cut is exact for spilled pages too
            // (reload reads only `rows * d` floats).
            if let Some(p) = self.layers[li].last_mut() {
                let prior = start - p.rows;
                let keep_rows = target - prior;
                if keep_rows < p.rows {
                    p.rows = keep_rows;
                    if let PageData::Resident { k, v } = &mut p.data {
                        k.truncate(keep_rows * self.d);
                        v.truncate(keep_rows * self.d);
                    }
                }
            }
            debug_assert_eq!(self.layer_rows(li), target);
        }
    }

    fn view(&self, li: usize) -> Vec<KvPageRef<'_>> {
        self.layers[li]
            .iter()
            .map(|p| match &p.data {
                PageData::Resident { k, v } => KvPageRef { k, v },
                PageData::Spilled { .. } => {
                    panic!("kv view: layer {li} has a spilled page — pin_layer first")
                }
            })
            .collect()
    }

    fn snapshot(&self) -> Vec<(Vec<f32>, Vec<f32>)> {
        self.layers
            .iter()
            .map(|pages| {
                let rows: usize = pages.iter().map(|p| p.rows).sum();
                let mut ks = Vec::with_capacity(rows * self.d);
                let mut vs = Vec::with_capacity(rows * self.d);
                for p in pages {
                    match &p.data {
                        PageData::Resident { k, v } => {
                            ks.extend_from_slice(k);
                            vs.extend_from_slice(v);
                        }
                        PageData::Spilled { slot } => {
                            let (k, v) = self.spill.read(*slot, p.rows * self.d);
                            ks.extend_from_slice(&k);
                            vs.extend_from_slice(&v);
                        }
                    }
                }
                (ks, vs)
            })
            .collect()
    }
}

impl Clone for BoundedKv {
    /// Deep copy, preserving the resident/spilled arrangement (spilled
    /// pages are re-read from the source file and re-spilled into the
    /// clone's own file).
    fn clone(&self) -> BoundedKv {
        let mut out = BoundedKv::new(
            self.layers.len(),
            self.d,
            self.page_rows,
            self.budget,
            Some(self.spill.dir.clone()),
        );
        out.clock = self.clock;
        for (li, pages) in self.layers.iter().enumerate() {
            for p in pages {
                let (data, resident) = match &p.data {
                    PageData::Resident { k, v } => (
                        PageData::Resident {
                            k: k.clone(),
                            v: v.clone(),
                        },
                        true,
                    ),
                    PageData::Spilled { slot } => {
                        let (k, v) = self.spill.read(*slot, p.rows * self.d);
                        let slot = out.spill.alloc();
                        out.spill.write(slot, &k, &v);
                        (PageData::Spilled { slot }, false)
                    }
                };
                if resident {
                    out.note_resident(1);
                }
                out.layers[li].push(Page {
                    rows: p.rows,
                    last_used: p.last_used,
                    data,
                });
            }
        }
        out.resident_peak = self.resident_peak.max(out.resident_peak);
        out
    }
}

/// Lazily-created spill file: fixed-size slots (one page's K then V,
/// padded to capacity) with a free list.
#[derive(Debug)]
struct Spill {
    dir: PathBuf,
    path: Option<PathBuf>,
    file: Option<File>,
    /// Per-side slot capacity in floats (`page_rows * d`).
    slot_floats: usize,
    free: Vec<u64>,
    next: u64,
}

impl Spill {
    fn new(dir: Option<PathBuf>, slot_floats: usize) -> Spill {
        Spill {
            dir: dir.unwrap_or_else(std::env::temp_dir),
            path: None,
            file: None,
            slot_floats,
            free: Vec::new(),
            next: 0,
        }
    }

    fn slot_bytes(&self) -> u64 {
        (self.slot_floats * 2 * 4) as u64
    }

    fn alloc(&mut self) -> u64 {
        self.free.pop().unwrap_or_else(|| {
            let s = self.next;
            self.next += 1;
            s
        })
    }

    fn free(&mut self, slot: u64) {
        self.free.push(slot);
    }

    fn ensure_file(&mut self) -> &File {
        if self.file.is_none() {
            let name = format!(
                "dtrnet-kv-{}-{}.spill",
                std::process::id(),
                SPILL_ID.fetch_add(1, Ordering::Relaxed)
            );
            let path = self.dir.join(name);
            let file = OpenOptions::new()
                .read(true)
                .write(true)
                .create(true)
                .truncate(true)
                .open(&path)
                .unwrap_or_else(|e| panic!("kv spill: create {}: {e}", path.display()));
            self.path = Some(path);
            self.file = Some(file);
        }
        self.file.as_ref().unwrap()
    }

    fn write(&mut self, slot: u64, k: &[f32], v: &[f32]) {
        let base = slot * self.slot_bytes();
        let v_off = base + (self.slot_floats * 4) as u64;
        let f = self.ensure_file();
        write_f32s(f, base, k);
        write_f32s(f, v_off, v);
    }

    fn read(&self, slot: u64, floats: usize) -> (Vec<f32>, Vec<f32>) {
        let f = self.file.as_ref().expect("kv spill: read before any write");
        let base = slot * self.slot_bytes();
        let v_off = base + (self.slot_floats * 4) as u64;
        (read_f32s(f, base, floats), read_f32s(f, v_off, floats))
    }
}

impl Drop for Spill {
    fn drop(&mut self) {
        if let Some(path) = self.path.take() {
            self.file = None;
            let _ = std::fs::remove_file(path);
        }
    }
}

fn write_f32s(mut f: &File, off: u64, data: &[f32]) {
    let mut buf = Vec::with_capacity(data.len() * 4);
    for &x in data {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    f.seek(SeekFrom::Start(off)).expect("kv spill: seek");
    f.write_all(&buf).expect("kv spill: write");
}

fn read_f32s(mut f: &File, off: u64, n: usize) -> Vec<f32> {
    let mut buf = vec![0u8; n * 4];
    f.seek(SeekFrom::Start(off)).expect("kv spill: seek");
    f.read_exact(&mut buf).expect("kv spill: read");
    buf.chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn row(rng: &mut Rng, d: usize) -> Vec<f32> {
        (0..d).map(|_| rng.normal() as f32).collect()
    }

    /// Drive a resident and a bounded cache through the same mixed
    /// append/pin/truncate trace and require bit-identical snapshots.
    #[test]
    fn bounded_matches_resident_bitwise_under_pressure() {
        let (n_layers, d, page_rows, budget) = (3usize, 8usize, 4usize, 12usize);
        let mut rng = Rng::new(42);
        let mut res = KvCache::resident(n_layers);
        let mut bnd = KvCache::bounded(n_layers, d, page_rows, budget, None);
        for step in 0..120u64 {
            let li = (rng.below(n_layers as u64)) as usize;
            let (k, v) = (row(&mut rng, d), row(&mut rng, d));
            // Interleave pins the way attention does, forcing evictions.
            res.pin_layer(li);
            bnd.pin_layer(li);
            res.append_row(li, &k, &v);
            bnd.append_row(li, &k, &v);
            if step % 17 == 16 {
                // Speculative-style rollback: cut every layer by up to 2.
                let lens: Vec<usize> =
                    res.lens(d).iter().map(|&l| l.saturating_sub(2)).collect();
                res.truncate(&lens, d);
                bnd.truncate(&lens, d);
                assert_eq!(bnd.lens(d), lens);
            }
        }
        assert_eq!(res.lens(d), bnd.lens(d));
        assert_eq!(res.snapshot(), bnd.snapshot(), "paged cache diverged from slab");
        // Pressure was real: more pages exist than the budget allows...
        let total_pages: usize =
            bnd.lens(d).iter().map(|l| l.div_ceil(page_rows)).sum();
        assert!(total_pages > budget, "test did not exercise eviction");
        // ...yet the resident high-water mark respected it (no single
        // layer's working set exceeded the budget here).
        assert!(
            bnd.resident_pages_peak() <= budget,
            "peak {} exceeded budget {budget}",
            bnd.resident_pages_peak()
        );
        assert!(bnd.resident_pages_peak() >= bnd.resident_pages().unwrap());
    }

    /// Views must reproduce the flat slab row-for-row after eviction
    /// round-trips, and pin_layer must make every page resident.
    #[test]
    fn pinned_view_concatenates_to_snapshot() {
        let (n_layers, d, page_rows, budget) = (2usize, 4usize, 2usize, 2usize);
        let mut rng = Rng::new(7);
        let mut kv = KvCache::bounded(n_layers, d, page_rows, budget, None);
        for _ in 0..9 {
            for li in 0..n_layers {
                let (k, v) = (row(&mut rng, d), row(&mut rng, d));
                kv.pin_layer(li);
                kv.append_row(li, &k, &v);
            }
        }
        for li in 0..n_layers {
            kv.pin_layer(li);
            let flat = kv.snapshot()[li].clone();
            let view = kv.view(li, d);
            let mut k = Vec::new();
            let mut v = Vec::new();
            for p in &view {
                k.extend_from_slice(p.k);
                v.extend_from_slice(p.v);
            }
            assert_eq!((k, v), flat);
            assert!(view.iter().all(|p| p.rows(d) <= page_rows));
        }
    }

    /// Clone preserves contents (including spilled pages) bit-exactly
    /// and writes into its own spill file.
    #[test]
    fn clone_preserves_spilled_pages() {
        let (n_layers, d, page_rows, budget) = (4usize, 4usize, 2usize, 2usize);
        let mut rng = Rng::new(11);
        let mut kv = KvCache::bounded(n_layers, d, page_rows, budget, None);
        for li in 0..n_layers {
            for _ in 0..5 {
                kv.pin_layer(li);
                let (k, v) = (row(&mut rng, d), row(&mut rng, d));
                kv.append_row(li, &k, &v);
            }
        }
        let cl = kv.clone();
        assert_eq!(cl.snapshot(), kv.snapshot());
        assert_eq!(cl.lens(d), kv.lens(d));
        // Mutating the clone must not affect the original.
        let mut cl = cl;
        let lens: Vec<usize> = cl.lens(d).iter().map(|&l| l / 2).collect();
        cl.truncate(&lens, d);
        assert_ne!(cl.lens(d), kv.lens(d));
    }

    /// Truncate must free spilled slots and handle partial-page cuts on
    /// spilled pages (prefix reload stays exact).
    #[test]
    fn truncate_partial_spilled_page_is_exact() {
        let (d, page_rows, budget) = (4usize, 4usize, 1usize);
        let mut rng = Rng::new(3);
        let mut res = KvCache::resident(2);
        let mut kv = KvCache::bounded(2, d, page_rows, budget, None);
        for _ in 0..6 {
            for li in 0..2 {
                let (k, v) = (row(&mut rng, d), row(&mut rng, d));
                res.append_row(li, &k, &v);
                kv.pin_layer(li);
                kv.append_row(li, &k, &v);
            }
        }
        // Layer 0's pages are spilled now (layer 1 was pinned last);
        // cut mid-page without pinning first.
        res.truncate(&[5, 2], d);
        kv.truncate(&[5, 2], d);
        assert_eq!(kv.snapshot(), res.snapshot());
    }
}
