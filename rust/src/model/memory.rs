//! Analytical KV-cache memory model (paper Fig. 6).
//!
//! DTRNet allocates KV only for routed tokens (the decode path appends per
//! layer only on routing). MoD likewise caches only selected tokens.
//! D-LLM — per the paper's observation — *masks* rather than evicts, so
//! its real footprint matches the dense Transformer; we model both its
//! nominal ("would-be") and actual footprints.

use crate::config::{LayerKind, ModelConfig};
#[cfg(test)]
use crate::config::Variant;

/// Bytes per cached element (the paper's serving setup uses fp16).
pub const KV_ELEM_BYTES: usize = 2;

/// Memory model for one architecture at one sequence length.
#[derive(Debug, Clone)]
pub struct KvMemoryModel {
    /// Actual allocated bytes (what a routing-aware pool holds).
    pub allocated_bytes: f64,
    /// Dense-equivalent bytes (the baseline it is compared against).
    pub dense_bytes: f64,
}

impl KvMemoryModel {
    /// Allocated bytes as a fraction of the dense-equivalent footprint.
    pub fn ratio(&self) -> f64 {
        self.allocated_bytes / self.dense_bytes
    }
}

/// KV bytes for a single sequence of length `n`. `fracs`: measured
/// attention fractions per layer (None → analytic defaults).
pub fn kv_bytes(cfg: &ModelConfig, n: usize, fracs: Option<&[f64]>) -> KvMemoryModel {
    let per_tok_layer = (2 * cfg.d_model * KV_ELEM_BYTES) as f64; // K + V
    let n = n as f64;
    let mut allocated = 0.0;
    let mut dense = 0.0;
    for (i, kind) in cfg.layer_kinds().iter().enumerate() {
        dense += n * per_tok_layer;
        let f = fracs.map(|v| v[i]).unwrap_or_else(|| cfg.attn_frac(i));
        let eff = match kind {
            LayerKind::Dense => 1.0,
            LayerKind::Dtr => f,
            LayerKind::Mod => f,
            // D-LLM masks the KV cache instead of evicting — footprint
            // stays dense (paper §Memory Efficiency Analysis).
            LayerKind::Dllm => 1.0,
        };
        allocated += eff * n * per_tok_layer;
    }
    KvMemoryModel {
        allocated_bytes: allocated,
        dense_bytes: dense,
    }
}

/// Convenience: the Fig.-6 series — KV MB vs sequence length.
pub fn kv_curve(cfg: &ModelConfig, lengths: &[usize]) -> Vec<(usize, f64)> {
    lengths
        .iter()
        .map(|&n| (n, kv_bytes(cfg, n, None).allocated_bytes / 1e6))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtr_saves_memory_dllm_does_not() {
        let dtr = ModelConfig::preset("smollm-1b3", Variant::DtrBilayer);
        let dllm = ModelConfig::preset("smollm-1b3", Variant::Dllm);
        let dense = ModelConfig::preset("smollm-1b3", Variant::Dense);
        let n = 8192;
        let m_dtr = kv_bytes(&dtr, n, None);
        let m_dllm = kv_bytes(&dllm, n, None);
        let m_dense = kv_bytes(&dense, n, None);
        assert!(m_dtr.ratio() < 0.7, "DTRNet should save: {}", m_dtr.ratio());
        // D-LLM's actual footprint ≈ dense (masking, not eviction).
        assert!((m_dllm.allocated_bytes - m_dense.allocated_bytes).abs() < 1e-6);
    }

    #[test]
    fn memory_grows_linearly() {
        let cfg = ModelConfig::preset("smollm-1b3", Variant::DtrBilayer);
        let curve = kv_curve(&cfg, &[1024, 2048, 4096]);
        let r1 = curve[1].1 / curve[0].1;
        let r2 = curve[2].1 / curve[1].1;
        assert!((r1 - 2.0).abs() < 1e-9 && (r2 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn mod_between_dense_and_dtr() {
        let n = 4096;
        let dtr = kv_bytes(&ModelConfig::preset("smollm-1b3", Variant::DtrBilayer), n, None);
        let m = kv_bytes(&ModelConfig::preset("smollm-1b3", Variant::Mod), n, None);
        assert!(dtr.allocated_bytes < m.allocated_bytes);
        assert!(m.ratio() < 1.0);
    }
}
