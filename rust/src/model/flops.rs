//! Analytical FLOPs model (paper Fig. 4: "Theoretical FLOPs comparison").
//!
//! Counts multiply-accumulates ×2, per token, forward pass, causal
//! attention averaged over positions ((n+1)/2 context per query). The
//! routing fraction per layer comes from `ModelConfig::attn_frac`
//! (analytic default 0.10 for trained DTR layers; measured values can be
//! substituted by the caller — `fig5_routing` feeds measured fractions
//! back into this model).

use crate::config::{LayerKind, ModelConfig, Variant};

/// Per-layer FLOPs decomposition (per token, forward).
#[derive(Debug, Clone, Default)]
pub struct FlopsBreakdown {
    /// Router MLP cost (DTR layers only).
    pub router: f64,
    /// Q/K/V/O projection cost for routed tokens.
    pub qkvo_proj: f64,
    /// Attention score + weighted-sum cost (the quadratic term).
    pub attn_mix: f64,
    /// Linear-bypass cost for non-routed tokens.
    pub bypass: f64,
    /// SwiGLU MLP cost (every token, both paths).
    pub mlp: f64,
}

impl FlopsBreakdown {
    /// Sum of all components.
    pub fn total(&self) -> f64 {
        self.router + self.qkvo_proj + self.attn_mix + self.bypass + self.mlp
    }
}

/// FLOPs per token for layer `i` at sequence length `n`, given the
/// fraction `f` of tokens routed to attention at that layer.
pub fn flops_per_layer(cfg: &ModelConfig, i: usize, n: usize, f: f64) -> FlopsBreakdown {
    let d = cfg.d_model as f64;
    let ff = cfg.d_ff as f64;
    let n = n as f64;
    let kind = cfg.layer_kinds()[i];
    // Average causal context per routed query: only routed tokens hold KV,
    // so the effective context is f·(n+1)/2.
    let ctx = |frac: f64| frac * (n + 1.0) / 2.0;
    match kind {
        LayerKind::Dense => FlopsBreakdown {
            router: 0.0,
            qkvo_proj: 8.0 * d * d,
            attn_mix: 4.0 * d * ctx(1.0),
            bypass: 0.0,
            mlp: 6.0 * d * ff,
        },
        LayerKind::Dtr => FlopsBreakdown {
            // two-layer router: d×(d/2) + (d/2)×2 mat-vecs
            router: d * d + 2.0 * d,
            // routed tokens pay Q,K,V,O; bypassed pay V,O only
            qkvo_proj: f * 8.0 * d * d,
            attn_mix: f * 4.0 * d * ctx(f),
            bypass: (1.0 - f) * 4.0 * d * d,
            mlp: 6.0 * d * ff, // MLP retained for ALL tokens (the paper's point)
        },
        LayerKind::Mod => FlopsBreakdown {
            router: 2.0 * d + 2.0 * d, // router + inference classifier
            qkvo_proj: f * 8.0 * d * d,
            attn_mix: f * 4.0 * d * ctx(f),
            bypass: 0.0,
            mlp: f * 6.0 * d * ff, // skipped tokens lose the MLP too
        },
        LayerKind::Dllm => FlopsBreakdown {
            router: d * d + 2.0 * d,
            qkvo_proj: f * 8.0 * d * d,
            attn_mix: f * 4.0 * d * ctx(f),
            bypass: 0.0,
            mlp: f * 6.0 * d * ff,
        },
    }
}

/// Dense-equivalent FLOPs for a single token at absolute context length
/// `ctx_len` (the token's position + 1): QKVO projections + attention
/// mix over exactly `ctx_len` cached tokens + MLP. This is the per-row
/// exact form of the dense branch of [`flops_per_layer`] — summing it
/// over rows `p = 0..n` reproduces the averaged analytic value times `n`
/// (Σ(p+1) = n(n+1)/2). The measured-FLOPs path
/// ([`crate::telemetry::FlopCounters`]) accumulates this per processed
/// row as the `dense_equiv` denominator of its per-layer
/// FLOPs-vs-dense ratio.
pub fn dense_flops_per_token(cfg: &ModelConfig, ctx_len: usize) -> f64 {
    let d = cfg.d_model as f64;
    let ff = cfg.d_ff as f64;
    8.0 * d * d + 4.0 * d * ctx_len as f64 + 6.0 * d * ff
}

/// Total forward FLOPs per token at sequence length `n`, including the
/// embedding/unembedding matmul. `fracs`: per-layer attention fraction
/// override (None → analytic defaults from the config).
pub fn flops_forward(cfg: &ModelConfig, n: usize, fracs: Option<&[f64]>) -> f64 {
    let mut total = 2.0 * cfg.d_model as f64 * cfg.vocab_size as f64; // unembed
    for i in 0..cfg.n_layers {
        let f = fracs.map(|v| v[i]).unwrap_or_else(|| cfg.attn_frac(i));
        total += flops_per_layer(cfg, i, n, f).total();
    }
    total
}

/// FLOPs ratio of `cfg` vs its dense twin at sequence length `n` — the
/// quantity on Fig. 4's y-axis.
pub fn flops_ratio_vs_dense(cfg: &ModelConfig, n: usize, fracs: Option<&[f64]>) -> f64 {
    let dense = ModelConfig {
        variant: Variant::Dense,
        ..cfg.clone()
    };
    flops_forward(cfg, n, fracs) / flops_forward(&dense, n, None)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_cfg(variant: Variant) -> ModelConfig {
        ModelConfig::preset("smollm-1b3", variant)
    }

    #[test]
    fn dense_ratio_is_one() {
        let c = paper_cfg(Variant::Dense);
        assert!((flops_ratio_vs_dense(&c, 2048, None) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn dtr_saves_more_with_length() {
        // Fig. 4's qualitative claim: DTRNet's FLOPs ratio declines faster
        // with sequence length than MoD/D-LLM.
        let dtr = paper_cfg(Variant::DtrBilayer);
        let r2k = flops_ratio_vs_dense(&dtr, 2048, None);
        let r20k = flops_ratio_vs_dense(&dtr, 20480, None);
        assert!(r20k < r2k, "ratio should fall with n: {r2k} -> {r20k}");
        let m = paper_cfg(Variant::Mod);
        let d = paper_cfg(Variant::Dllm);
        let rm = flops_ratio_vs_dense(&m, 20480, None);
        let rd = flops_ratio_vs_dense(&d, 20480, None);
        assert!(
            r20k < rm && r20k < rd,
            "DTRNet {r20k} must beat MoD {rm} and D-LLM {rd} at 20k"
        );
    }

    #[test]
    fn ratio_in_paper_ballpark_at_20k() {
        // Paper: DTRNet ≈ 0.785 at 20k, MoD/D-LLM ≈ 0.82. Our analytic
        // model with default fractions should land in the same region
        // (±0.1 — the paper's exact constant depends on their counting).
        let dtr = paper_cfg(Variant::DtrBilayer);
        let r = flops_ratio_vs_dense(&dtr, 20480, None);
        assert!(r > 0.55 && r < 0.9, "r={r}");
    }

    #[test]
    fn skip_variant_cheapest() {
        let skip = paper_cfg(Variant::DtrSkip);
        let bi = paper_cfg(Variant::DtrBilayer);
        assert!(
            flops_forward(&skip, 2048, None) < flops_forward(&bi, 2048, None)
        );
    }

    #[test]
    fn measured_fracs_override() {
        let c = paper_cfg(Variant::DtrBilayer);
        let hi = vec![1.0; c.n_layers];
        let lo = vec![0.05; c.n_layers];
        assert!(
            flops_forward(&c, 2048, Some(&hi)) > flops_forward(&c, 2048, Some(&lo))
        );
    }
}
