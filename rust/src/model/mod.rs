//! Host-side model analytics.
//!
//! The paper's Figs. 4 and 6 are *analytical* (theoretical FLOPs, KV-cache
//! bytes); this module implements those models exactly so the benches can
//! regenerate the figures at both the paper's scales (smollm-1b3) and the
//! testbed scales (tiny) — and so the coordinator can make capacity
//! decisions without touching the device.

pub mod flops;
pub mod memory;

pub use flops::{flops_forward, flops_per_layer, FlopsBreakdown};
pub use memory::{kv_bytes, KvMemoryModel};
