//! Data pipeline: corpora, synthetic task generators, deterministic batching.
//!
//! The paper trains on FineWeb-Edu (15B/100B tokens); offline we substitute
//! (a) an embedded tiny English corpus, (b) a Zipf-Markov synthetic LM
//! corpus with controllable structure, and (c) long-context probe tasks
//! (needle-recall / copy) for the Fig. 3 extrapolation benchmarks. All
//! generation is seed-deterministic.

pub mod corpus;
pub mod longctx;
pub mod stream;

use crate::util::rng::Rng;

pub use corpus::{embedded_corpus, markov_corpus, CorpusStats};
pub use longctx::{copy_task, needle_task};
pub use stream::BatchStream;

/// A tokenized dataset split into fixed-length training windows.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// The flat token stream batches are cut from.
    pub tokens: Vec<u32>,
    /// Sequence length of every batch row.
    pub seq: usize,
}

impl Dataset {
    /// Wrap a token stream for `seq`-length batching.
    pub fn new(tokens: Vec<u32>, seq: usize) -> Dataset {
        assert!(tokens.len() > seq, "corpus shorter than one window");
        Dataset { tokens, seq }
    }

    /// Number of non-overlapping windows.
    pub fn n_windows(&self) -> usize {
        self.tokens.len() / self.seq
    }

    /// The `i`-th window (wrapping), as i32 for the runtime literals.
    pub fn window(&self, i: usize) -> Vec<i32> {
        let w = self.n_windows();
        let start = (i % w) * self.seq;
        self.tokens[start..start + self.seq]
            .iter()
            .map(|&t| t as i32)
            .collect()
    }

    /// A [batch, seq] matrix of random windows, flattened row-major.
    pub fn sample_batch(&self, rng: &mut Rng, batch: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * self.seq);
        for _ in 0..batch {
            let i = rng.usize_below(self.n_windows());
            out.extend(self.window(i));
        }
        out
    }

    /// Deterministic sequential batches for evaluation (no sampling).
    pub fn eval_batches(&self, batch: usize) -> impl Iterator<Item = Vec<i32>> + '_ {
        let n = self.n_windows() / batch;
        (0..n).map(move |b| {
            let mut out = Vec::with_capacity(batch * self.seq);
            for j in 0..batch {
                out.extend(self.window(b * batch + j));
            }
            out
        })
    }

    /// Train/held-out split by window, deterministic.
    pub fn split(&self, eval_fraction: f64) -> (Dataset, Dataset) {
        let w = self.n_windows();
        let n_eval = ((w as f64 * eval_fraction) as usize).max(1);
        let cut = (w - n_eval) * self.seq;
        (
            Dataset::new(self.tokens[..cut].to_vec(), self.seq),
            Dataset::new(self.tokens[cut..].to_vec(), self.seq),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windows_and_batches() {
        let d = Dataset::new((0..1000u32).collect(), 100);
        assert_eq!(d.n_windows(), 10);
        assert_eq!(d.window(0)[0], 0);
        assert_eq!(d.window(1)[0], 100);
        assert_eq!(d.window(10)[0], 0); // wraps
        let mut rng = Rng::new(0);
        let b = d.sample_batch(&mut rng, 3);
        assert_eq!(b.len(), 300);
    }

    #[test]
    fn split_disjoint() {
        let d = Dataset::new((0..1000u32).collect(), 100);
        let (tr, ev) = d.split(0.2);
        assert_eq!(tr.n_windows(), 8);
        assert_eq!(ev.n_windows(), 2);
        assert_eq!(ev.window(0)[0], 800);
    }
}
