//! Streaming batch pipeline: background workers + bounded channel.
//!
//! The trainer consumes batches through a bounded queue filled by worker
//! threads — the data-parallel input pipeline of a real training system,
//! with backpressure (workers block when the trainer falls behind the
//! queue depth) and deterministic per-worker seeding (run reproducibility
//! does not depend on thread scheduling: batch `i` is always produced from
//! stream `i % workers` with counter `i / workers`).

use std::sync::mpsc;
use std::sync::Arc;
use std::thread;

use super::Dataset;
use crate::util::rng::Rng;

/// A produced training batch: `[batch, seq]` row-major token ids.
#[derive(Debug)]
pub struct StreamBatch {
    /// Position of this batch within the epoch.
    pub index: usize,
    /// Row-major `[batch, seq]` token ids.
    pub tokens: Vec<i32>,
}

/// Handle to the background pipeline; `next()` blocks on the queue.
pub struct BatchStream {
    /// Option so Drop can disconnect the channel (unblocking producers
    /// parked on a full bounded queue) *before* joining the workers.
    rx: Option<mpsc::Receiver<StreamBatch>>,
    workers: Vec<thread::JoinHandle<()>>,
    /// Reorder buffer: batches may complete out of order across workers.
    pending: std::collections::BTreeMap<usize, StreamBatch>,
    next_index: usize,
}

impl BatchStream {
    /// Spawn `workers` producer threads generating `total` batches of
    /// `batch` windows each from `data`, queue bounded at `depth`.
    pub fn spawn(
        data: Arc<Dataset>,
        batch: usize,
        total: usize,
        workers: usize,
        depth: usize,
        seed: u64,
    ) -> BatchStream {
        let workers_n = workers.max(1);
        let (tx, rx) = mpsc::sync_channel::<StreamBatch>(depth.max(1));
        let handles = (0..workers_n)
            .map(|w| {
                let data = Arc::clone(&data);
                let tx = tx.clone();
                thread::spawn(move || {
                    // Deterministic: stream w produces batches w, w+W, w+2W…
                    // each from an rng seeded by (seed, w, counter).
                    let mut i = w;
                    let mut counter = 0u64;
                    while i < total {
                        let mut rng =
                            Rng::new(seed ^ (w as u64) << 32 ^ counter.wrapping_mul(0x9e37));
                        let tokens = data.sample_batch(&mut rng, batch);
                        if tx.send(StreamBatch { index: i, tokens }).is_err() {
                            return; // consumer dropped
                        }
                        i += workers_n;
                        counter += 1;
                    }
                })
            })
            .collect();
        BatchStream {
            rx: Some(rx),
            workers: handles,
            pending: Default::default(),
            next_index: 0,
        }
    }

    /// Next batch in index order (blocks; None when the stream is done).
    pub fn next(&mut self) -> Option<StreamBatch> {
        let rx = self.rx.as_ref().expect("stream closed");
        loop {
            if let Some(b) = self.pending.remove(&self.next_index) {
                self.next_index += 1;
                return Some(b);
            }
            match rx.recv() {
                Ok(b) => {
                    self.pending.insert(b.index, b);
                }
                Err(_) => {
                    // producers done; drain the reorder buffer
                    return self.pending.remove(&self.next_index).map(|b| {
                        self.next_index += 1;
                        b
                    });
                }
            }
        }
    }
}

impl Drop for BatchStream {
    fn drop(&mut self) {
        // Disconnect first: dropping the receiver makes every blocked
        // send() fail, so producers exit regardless of queue state.
        drop(self.rx.take());
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn data() -> Arc<Dataset> {
        Arc::new(Dataset::new((0..10_000u32).collect(), 50))
    }

    #[test]
    fn produces_all_batches_in_order() {
        let mut s = BatchStream::spawn(data(), 4, 23, 3, 4, 7);
        let mut seen = 0;
        while let Some(b) = s.next() {
            assert_eq!(b.index, seen);
            assert_eq!(b.tokens.len(), 4 * 50);
            seen += 1;
        }
        assert_eq!(seen, 23);
    }

    #[test]
    fn deterministic_across_worker_counts() {
        // batch i's content is a function of (seed, i) only — invariant to
        // worker parallelism
        let collect = |workers| {
            let mut s = BatchStream::spawn(data(), 2, 10, workers, 4, 9);
            let mut out = Vec::new();
            while let Some(b) = s.next() {
                out.push(b.tokens);
            }
            out
        };
        // note: stream identity = i % workers, so equality holds only for
        // equal worker counts; check reproducibility at fixed parallelism
        assert_eq!(collect(3), collect(3));
        assert_eq!(collect(1), collect(1));
    }

    #[test]
    fn bounded_queue_backpressure() {
        // tiny depth with a slow consumer must still complete
        let mut s = BatchStream::spawn(data(), 2, 12, 2, 1, 3);
        let mut n = 0;
        while let Some(_b) = s.next() {
            std::thread::sleep(std::time::Duration::from_millis(1));
            n += 1;
        }
        assert_eq!(n, 12);
    }

    #[test]
    fn early_drop_does_not_hang() {
        let s = BatchStream::spawn(data(), 2, 1000, 2, 2, 11);
        drop(s); // must join cleanly without consuming
    }
}
