//! Long-context probe tasks (Fig. 3 substitutes).
//!
//! The paper evaluates 20k-token LongLM suites (BookSum, NarrativeQA,
//! PG-19, …). Offline we generate tasks that exercise the same capability
//! axes — long-range recall and copy fidelity — at arbitrary lengths:
//!
//! * `needle_task`: a key-value "needle" planted early in a haystack of
//!   filler; the continuation requires recalling the value at the end.
//! * `copy_task`: a marker followed by a block that must be copied after a
//!   long gap — stresses positional extrapolation directly.
//!
//! Perplexity on the *answer span* of these sequences is the reported
//! metric, mirroring the paper's ppl-vs-length curves.

use crate::util::rng::Rng;

/// A long-context evaluation item: full token sequence plus the span
/// (start, end) over which perplexity should be measured.
#[derive(Debug, Clone)]
pub struct LongCtxItem {
    /// Full prompt: haystack with the needle embedded.
    pub tokens: Vec<u32>,
    /// Needle span start (token index).
    pub answer_start: usize,
    /// Needle span end (exclusive).
    pub answer_end: usize,
}

fn filler(rng: &mut Rng, vocab: usize, len: usize, out: &mut Vec<u32>) {
    // Low-entropy filler (repeated trigrams) so the model's ppl on filler
    // is stable and the answer span dominates the signal.
    let a = rng.below(vocab as u64) as u32;
    let b = rng.below(vocab as u64) as u32;
    for i in 0..len {
        out.push(match i % 4 {
            0 => a,
            1 => b,
            2 => a,
            _ => rng.below(vocab as u64) as u32,
        });
    }
}

/// Needle-recall: `[needle] [filler...] [needle repeated]`; the answer span
/// is the trailing repetition (recallable only via long-range attention).
pub fn needle_task(rng: &mut Rng, vocab: usize, total_len: usize, needle_len: usize) -> LongCtxItem {
    assert!(total_len > 2 * needle_len + 8);
    let needle: Vec<u32> = (0..needle_len)
        .map(|_| rng.below(vocab as u64) as u32)
        .collect();
    let mut tokens = Vec::with_capacity(total_len);
    tokens.extend_from_slice(&needle);
    filler(rng, vocab, total_len - 2 * needle_len, &mut tokens);
    let answer_start = tokens.len();
    tokens.extend_from_slice(&needle);
    let answer_end = tokens.len();
    LongCtxItem {
        tokens,
        answer_start,
        answer_end,
    }
}

/// Copy task: `[block] [gap filler] [block]` with a larger copied block —
/// the long-range analogue of PG-19-style verbatim continuation.
pub fn copy_task(rng: &mut Rng, vocab: usize, total_len: usize, block_len: usize) -> LongCtxItem {
    assert!(total_len > 2 * block_len + 8);
    let block: Vec<u32> = (0..block_len)
        .map(|_| rng.below(vocab as u64) as u32)
        .collect();
    let mut tokens = Vec::with_capacity(total_len);
    tokens.extend_from_slice(&block);
    filler(rng, vocab, total_len - 2 * block_len, &mut tokens);
    let answer_start = tokens.len();
    tokens.extend_from_slice(&block);
    let answer_end = tokens.len();
    LongCtxItem {
        tokens,
        answer_start,
        answer_end,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn needle_layout() {
        let mut rng = Rng::new(1);
        let item = needle_task(&mut rng, 256, 512, 16);
        assert_eq!(item.tokens.len(), 512);
        assert_eq!(item.answer_end - item.answer_start, 16);
        // answer repeats the prefix needle
        assert_eq!(
            &item.tokens[..16],
            &item.tokens[item.answer_start..item.answer_end]
        );
    }

    #[test]
    fn copy_layout() {
        let mut rng = Rng::new(2);
        let item = copy_task(&mut rng, 256, 1024, 64);
        assert_eq!(item.tokens.len(), 1024);
        assert_eq!(
            &item.tokens[..64],
            &item.tokens[item.answer_start..item.answer_end]
        );
    }
}
