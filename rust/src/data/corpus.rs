//! Corpus sources: embedded tiny-English text + synthetic Zipf-Markov LM.
//!
//! The Markov corpus gives the model *learnable* structure (so loss curves
//! actually fall) with a controllable alphabet, while the embedded corpus
//! provides real-text byte statistics for perplexity evaluation.

use crate::util::rng::Rng;

/// A small embedded English corpus (public-domain style sentences,
/// repeated with variation) — the offline stand-in for WikiText/FineWeb.
pub fn embedded_corpus() -> String {
    // ~50 base sentences; the repetition-with-substitution below expands
    // them to a corpus large enough for a few hundred training windows.
    const BASE: &[&str] = &[
        "the sun rose over the quiet valley and the river ran silver in the light",
        "a small boat drifted along the shore while the fisherman mended his nets",
        "in the market the merchants called out the prices of bread and salt",
        "the old clock on the tower struck nine and the doves scattered into the sky",
        "she opened the heavy book and read the first line aloud to the children",
        "rain fell softly on the roof of the library where the students worked",
        "the mountain path turned sharply and revealed the whole plain below",
        "he carried the letters to the post office before the morning train left",
        "the garden smelled of mint and thyme after the long summer rain",
        "a gray cat slept on the warm stones beside the kitchen door",
        "the teacher drew a long line on the board and explained the theorem",
        "wind moved through the wheat field like a slow wave on the sea",
        "the baker set the fresh loaves in the window as the street filled with people",
        "two travelers shared their bread and told stories of distant cities",
        "the lamp flickered once and then burned steady through the night",
        "the carpenter measured the plank twice and cut it once with care",
        "snow settled on the pines and the trail vanished under a white sheet",
        "the young engineer checked the bridge cables one bolt at a time",
        "a bell rang across the harbor and the ships answered with their horns",
        "the museum kept a map of the old kingdom drawn on yellow parchment",
        "the farmer counted the sheep as they passed through the narrow gate",
        "music drifted from the open window and mixed with the evening air",
        "the printer set the type letter by letter until the page was full",
        "a long road runs from the village to the sea through fields of barley",
        "the astronomer noted the position of the red star in her ledger",
        "the blacksmith struck the iron while it glowed orange on the anvil",
        "children chased the kite down the hill until the string slipped free",
        "the librarian stamped the card and slid the book across the desk",
        "fog covered the bay at dawn and lifted slowly as the sun climbed",
        "the tailor folded the cloth and marked the seams with white chalk",
        "a caravan of carts moved east carrying salt and dried fish",
        "the clerk added the figures in the ledger and found them correct",
        "lanterns lined the bridge during the festival of the first moon",
        "the surgeon washed her hands and asked for the smallest blade",
        "grapes hung heavy on the vine in the last warm week of autumn",
        "the captain read the chart and set the course two points north",
        "a letter arrived from the capital sealed with dark green wax",
        "the miller opened the gate and water turned the great wheel",
        "the scholar compared the two manuscripts line by careful line",
        "thunder rolled over the hills but the rain stayed far to the west",
    ];
    let mut out = String::new();
    // Deterministic expansion: rotate substitutions through the sentences.
    let subs = [
        ("the", "the"),
        ("old", "ancient"),
        ("small", "little"),
        ("long", "winding"),
        ("warm", "bright"),
    ];
    for round in 0..6 {
        for (i, s) in BASE.iter().enumerate() {
            let mut line = s.to_string();
            let (from, to) = subs[(round + i) % subs.len()];
            line = line.replacen(from, to, 1);
            out.push_str(&line);
            out.push_str(". ");
        }
    }
    out
}

/// Synthetic corpus from an order-1 Markov chain with Zipf-distributed
/// emissions over `vocab` symbols — learnable bigram structure whose
/// entropy a small model can visibly reduce within a few hundred steps.
pub fn markov_corpus(rng: &mut Rng, vocab: usize, len: usize, n_states: usize) -> Vec<u32> {
    assert!(vocab >= 2 && n_states >= 1);
    // Each state has a preferred emission table: a Zipf ordering that is a
    // random permutation per state, plus a sparse transition matrix.
    let mut perms: Vec<Vec<u32>> = Vec::with_capacity(n_states);
    for _ in 0..n_states {
        let mut p: Vec<u32> = (0..vocab as u32).collect();
        rng.shuffle(&mut p);
        perms.push(p);
    }
    let trans: Vec<Vec<usize>> = (0..n_states)
        .map(|_| (0..4).map(|_| rng.usize_below(n_states)).collect())
        .collect();
    let mut out = Vec::with_capacity(len);
    let mut state = 0usize;
    for _ in 0..len {
        let sym = perms[state][rng.zipf(vocab, 1.3)];
        out.push(sym);
        state = trans[state][rng.usize_below(4)];
    }
    out
}

/// Simple corpus statistics (entropy estimate, symbol coverage).
#[derive(Debug, Clone)]
pub struct CorpusStats {
    /// Token count.
    pub len: usize,
    /// Distinct token values observed.
    pub distinct: usize,
    /// Empirical unigram entropy in bits per token.
    pub unigram_entropy_bits: f64,
}

impl CorpusStats {
    /// Summary statistics of a token stream.
    pub fn of(tokens: &[u32], vocab: usize) -> CorpusStats {
        let mut counts = vec![0usize; vocab];
        for &t in tokens {
            counts[t as usize % vocab] += 1;
        }
        let n = tokens.len() as f64;
        let mut h = 0.0;
        let mut distinct = 0;
        for &c in &counts {
            if c > 0 {
                distinct += 1;
                let p = c as f64 / n;
                h -= p * p.log2();
            }
        }
        CorpusStats {
            len: tokens.len(),
            distinct,
            unigram_entropy_bits: h,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn embedded_is_substantial() {
        let c = embedded_corpus();
        assert!(c.len() > 10_000, "len={}", c.len());
        assert!(c.is_ascii());
    }

    #[test]
    fn markov_deterministic_and_structured() {
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let a = markov_corpus(&mut r1, 256, 5000, 8);
        let b = markov_corpus(&mut r2, 256, 5000, 8);
        assert_eq!(a, b);
        let stats = CorpusStats::of(&a, 256);
        // Zipf emissions → entropy well below uniform 8 bits.
        assert!(stats.unigram_entropy_bits < 7.5, "{stats:?}");
        assert!(stats.distinct > 50);
    }
}
